"""Skewed-contention benchmark: retry convergence under Zipf write traffic.

Storm's dataplane (§5.4) retries aborted transactions; this benchmark
quantifies what that buys under skew, sweeping the Zipf exponent:

  * commit rate of single-shot run_transactions (max_rounds=1) vs the
    bounded-retry tx_loop at max_rounds in {2, 4, 8};
  * aborts by cause (lock-race / validation / overflow back-pressure);
  * coalesced wire messages per committed transaction — the doorbell-batching
    payoff grows with skew because more lanes share a (src, dst) pair
    (cf. "RDMA vs. RPC for Implementing Distributed Data Structures":
    aggregation + retry policy dominates throughput under skew).

    PYTHONPATH=src python benchmarks/skew_contention.py [--smoke]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_line, time_jit
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.testing.workloads import value_for, zipf_write_keys

N_NODES = 4
LANES = 16
HOT_KEYS = 16


def run_config(theta: float, max_rounds: int, *, lanes=LANES, seed=11):
    cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(N_NODES)
    state = ht.init_cluster_state(cfg)

    hot, klo, khi = zipf_write_keys(N_NODES, lanes, n_hot=HOT_KEYS,
                                    theta=theta, seed=seed)
    # pre-insert the hot set so writes contend on existing rows
    from repro.core import rpc as R
    h = ht.make_rpc_handler(cfg, layout)
    hk = jnp.tile(hot[None], (N_NODES, 1))
    hz = jnp.zeros_like(hk)
    node, _, _ = ht.lookup_start(cfg, layout, hk, hz)
    state, _, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, hk, hz, value=value_for(hk)), h)

    rk = jnp.zeros((N_NODES, lanes, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo)

    @jax.jit
    def round_fn(state):
        st, _, res = txl.tx_loop(
            t, state, cfg, layout, read_keys=rk, write_keys=wk,
            write_values=wv, max_rounds=max_rounds)
        return st, res

    (state, res), dt = time_jit(round_fn, state)
    n_tx = N_NODES * lanes
    committed = int(jnp.sum(res.committed))
    retries = int(jnp.sum(res.round_retries))
    ab_lock = int(jnp.sum(res.round_abort_lock))
    ab_val = int(jnp.sum(res.round_abort_validate))
    ab_ovf = int(jnp.sum(res.round_abort_overflow))
    msgs = float(res.metrics.wire.messages)
    ops = float(res.metrics.wire.ops)
    # fused schedule: write-only tx -> lock round + commit round, ≤ 2
    # exchanges per attempted protocol round (parked rounds cost none)
    rounds_attempted = int((np.asarray(res.round_attempts) > 0).sum())
    rt_round = float(res.round_trips) / max(rounds_attempted, 1)
    assert rt_round <= 2.0, rt_round
    csv_line(f"skew/theta{theta}/r{max_rounds}", dt / n_tx * 1e6,
             f"commit_rate={committed / n_tx:.3f};retries={retries};"
             f"aborts_lock/val/ovf={ab_lock}/{ab_val}/{ab_ovf};"
             f"coalesced_msgs={msgs:.0f};per_op_msgs={2 * ops:.0f};"
             f"rt_round={rt_round:.2f}")
    return committed


def main(thetas=(0.6, 1.2), rounds=(1, 2, 4, 8)):
    for theta in thetas:
        base = None
        for r in rounds:
            c = run_config(theta, r)
            base = c if base is None else base
            if r >= 4:
                assert c >= base, "retries must never commit less work"
        print(f"# theta={theta}: commit counts over rounds {rounds} verified "
              f"monotone-from-single-shot")


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        main(thetas=(1.2,), rounds=(1, 4))
    else:
        main()
