"""Replication cost: what does surviving f node losses cost the dataplane?

Sweeps the replication factor f ∈ {0, 1, 2} over the SAME fixed OCC workload
(`common.make_tx_workload`, the one the bench gate snapshots) and reports,
per f:

  * exchange round trips — asserted IDENTICAL across f: backup writes ride
    the commit fused round as extra traffic classes
    (`tx.commit_or_abort`), so replication adds ZERO rounds to the fast
    path, only a wider commit fan-out;
  * wire cost — ops/tx, bytes/tx and coalesced messages/tx, which DO grow
    with f (the extra (src, dst) pairs `transport.wire_for_classes` prices);
  * modeled Mtx/node per connection mode at the emulated 96-node scale (the
    `nic.ConnTable` model prices the fan-out's per-op connection-state
    penalty) — the replication × connection-mode trade-off in one table.

f = 0 is asserted bit-identical to a run with no ReplicaConfig at all
(commit mask, wire ops, bytes, round trips) — the equivalence the test suite
(`tests/test_replication.py`) checks slot-by-slot.

A failure-injection section then populates THROUGH the replicated commit
path, kills a node (`replication.kill_node`), scorches its arena, and
re-reads every key via `replication.failover_lookup`: all reads must be
served by the surviving replicas.

    PYTHONPATH=src python benchmarks/replication_cost.py [--smoke]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import (csv_line, make_tx_workload, modeled_throughput_per_node,
                    time_jit)
from repro.core import nic as qn
from repro.core import telemetry as T
from repro.core import replication as repl
from repro.core import slots as sl
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.testing.workloads import value_for

SIM_NODES = 4
LANES = 32          # modeled pipeline depth (conn_scaling's)
EMULATED = (32, 96)


def run_f(t, cfg, layout, base_state, rk, wk, wv, rep, *, max_rounds=2,
          nic=None):
    @jax.jit
    def fn(state):
        st, _, res = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                                 write_keys=wk, write_values=wv,
                                 max_rounds=max_rounds, rep=rep, nic=nic)
        return st, res

    (st, res), dt = time_jit(fn, base_state, iters=1)
    return st, res, dt


def sweep_f(*, lanes: int, smoke: bool):
    cfg = ht.HashTableConfig(n_nodes=SIM_NODES, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(SIM_NODES)
    state = ht.init_cluster_state(cfg)
    state, rk, wk, wv = make_tx_workload(t, cfg, layout, state, lanes=lanes,
                                         n_keys=64, seed=5)
    n_tx = SIM_NODES * lanes

    _, res_none, _ = run_f(t, cfg, layout, state, rk, wk, wv, rep=None)
    rows = {}
    for f in (0, 1, 2):
        rep = repl.ReplicaConfig(SIM_NODES, f)
        _, res, dt = run_f(t, cfg, layout, state, rk, wk, wv, rep=rep)
        w = res.metrics.wire
        row = dict(
            round_trips=float(res.round_trips),
            ops_tx=float(w.ops) / n_tx,
            bytes_tx=float(w.total_bytes) / n_tx,
            msgs_tx=float(w.messages) / n_tx,
            commit_rate=float(jnp.mean(res.committed)),
        )
        rows[f] = row
        csv_line(f"replication/f{f}", dt / n_tx * 1e6,
                 f"round_trips={row['round_trips']:.0f};"
                 f"ops_tx={row['ops_tx']:.2f};bytes_tx={row['bytes_tx']:.0f};"
                 f"msgs_tx={row['msgs_tx']:.2f};"
                 f"commit_rate={row['commit_rate']:.3f}")

    # --- invariants the PR's acceptance criteria pin ------------------------
    w0, wn = rows[0], res_none.metrics.wire
    assert rows[0]["round_trips"] == float(res_none.round_trips)
    assert w0["ops_tx"] == float(wn.ops) / n_tx \
        and w0["bytes_tx"] == float(wn.total_bytes) / n_tx, \
        "f=0 must be bit-identical to the unreplicated dataplane"
    for f in (1, 2):
        assert rows[f]["round_trips"] == rows[0]["round_trips"], \
            f"f={f} must add ZERO exchange rounds (got {rows[f]['round_trips']} " \
            f"vs {rows[0]['round_trips']})"
        assert rows[f]["ops_tx"] > rows[f - 1]["ops_tx"]
        assert rows[f]["bytes_tx"] > rows[f - 1]["bytes_tx"]
    print(f"# f=1 adds 0 exchange rounds, "
          f"+{rows[1]['bytes_tx'] - rows[0]['bytes_tx']:.0f} bytes/tx; "
          f"f=2 +{rows[2]['bytes_tx'] - rows[0]['bytes_tx']:.0f} bytes/tx")

    # --- replication x connection-mode: modeled Mtx/node at emulated scale --
    modes = (qn.RC_EXCLUSIVE, qn.DCT) if smoke else qn.MODES
    for m in EMULATED[-1:] if smoke else EMULATED:
        for mode in modes:
            ct = qn.ConnTable(n_nodes=m, threads=20, mode=mode)
            for f in (0, 1, 2):
                mops = modeled_mtx(rows[f], f, ct)
                csv_line(f"replication/model/{mode}/m{m}/f{f}", 1.0 / mops,
                         f"modeled_Mtx_node={mops:.2f};"
                         f"penalty_us_op={ct.penalty_us_per_op:.4f}")
    return rows


def modeled_mtx(row, f: int, ct) -> float:
    """Modeled Mtx/node: the per-tx protocol profile (2 one-sided exchanges,
    2 + f RPC-class exchanges — the commit round fans out to f extra
    destinations) priced with the measured wire bytes and the connection
    mode's per-op penalty applied to every delivered request."""
    return modeled_throughput_per_node(
        reads_per_op=2.0, rpcs_per_op=2.0 + f,
        wire_bytes_per_op=row["bytes_tx"], lanes=LANES,
        extra_cpu_us_per_op=ct.penalty_us_per_op * row["ops_tx"])


def failover_section(*, lanes: int):
    cfg = ht.HashTableConfig(n_nodes=SIM_NODES, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(SIM_NODES)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(17)
    klo = jnp.asarray(rng.randint(0, 2**31, (SIM_NODES, lanes, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (SIM_NODES, lanes, 1)), jnp.uint32)
    wv = value_for(klo + jnp.uint32(7))
    rep = repl.ReplicaConfig(SIM_NODES, 1)
    state, _, res = txl.tx_loop(
        t, state, cfg, layout,
        read_keys=jnp.zeros((SIM_NODES, lanes, 0, 2), jnp.uint32),
        write_keys=jnp.stack([klo, khi], -1), write_values=wv,
        max_rounds=4, rep=rep)
    assert bool(np.asarray(res.committed).all())

    dead = 1
    alive = repl.kill_node(repl.all_alive(SIM_NODES), dead)
    state = dict(state, arena=state["arena"].at[dead].set(jnp.uint32(0xDEAD)))
    out = repl.failover_lookup(t, state, klo[..., 0], khi[..., 0], cfg,
                               layout, rep, alive)
    found = np.asarray(out["found"])
    home = np.asarray(ht.home_of(cfg, klo[..., 0], khi[..., 0])[0])
    n_failover = int((home == dead).sum())
    assert found.all(), "reads must fail over to the backup copies"
    np.testing.assert_array_equal(
        np.asarray(out["value"]),
        np.asarray(wv.reshape(SIM_NODES, lanes, sl.VALUE_WORDS)))
    w = out["wire"]
    csv_line("replication/failover", 0.0,
             f"killed_node={dead};keys={found.size};rerouted={n_failover};"
             f"found_rate={found.mean():.3f};"
             f"ops={float(w.ops):.0f};round_trips={float(w.round_trips):.0f}")
    return dict(failover_reads=float(w.ops),
                failover_rerouted=n_failover,
                failover_round_trips=float(w.round_trips),
                found_rate=float(found.mean()))


def fill_registry(reg: T.MetricsRegistry, *, lanes: int = 8,
                  smoke: bool = True) -> T.MetricsRegistry:
    """Publish the replication bill to a MetricsRegistry (the metrics.json
    surface): per-f wire profile of the gate workload, plus the
    failure-injection section's failover reads (every read served by a
    surviving replica after a node death)."""
    rows = sweep_f(lanes=lanes, smoke=smoke)
    for f, row in rows.items():
        reg.set(f"replication.round_trips_f{f}", row["round_trips"])
        reg.set(f"replication.bytes_tx_f{f}", row["bytes_tx"])
        reg.set(f"replication.ops_tx_f{f}", row["ops_tx"])
        reg.set(f"replication.commit_rate_f{f}", row["commit_rate"])
    fo = failover_section(lanes=lanes)
    reg.incr("replication.failover_reads", fo["failover_reads"])
    reg.incr("replication.failover_rerouted", fo["failover_rerouted"])
    reg.set("replication.failover_round_trips", fo["failover_round_trips"])
    reg.set("replication.failover_found_rate", fo["found_rate"])
    return reg


def main(*, smoke: bool = False):
    lanes = 8 if smoke else 16
    sweep_f(lanes=lanes, smoke=smoke)
    failover_section(lanes=lanes)


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    main(smoke="--smoke" in sys.argv)
