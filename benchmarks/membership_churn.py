"""Membership churn: what do join / leave / kill cost the dataplane?

The placement subsystem's bargain is: epoch-STABLE execution pays nothing
(routing is a client-side table lookup; the published region is only read
after a stale-route abort), and every membership event is billed explicitly
— re-replication bytes, epoch-refresh round trips, and one round of
``stale_route`` aborts for clients caught with the old table.  This
benchmark measures each term of that bill on deterministic workloads:

  * ``steady``    — the bench-gate OCC workload (f=1) run twice, with and
    without a placement table.  Exchange rounds are asserted IDENTICAL: the
    identity table routes every key to its static home and the refresh read
    is gated off while no lane aborts stale, so placement adds ZERO wire to
    the epoch-stable fast path (the bench gate pins this forever).
  * ``refresh``   — one table refresh is ONE one-sided read of the
    coordinator-published routing region, ``placement.routing_words(n)``
    words; reported in round trips and bytes.
  * ``kill``      — fail a node at f=1: ``repair_plan`` promotes surviving
    copies and ``rereplicate`` streams the dead node's partitions to fresh
    backups over the existing backup classes.  Reports the re-replication
    bytes (the paper's recovery-traffic term) and the transfer count.
  * ``stale``     — a partition is migrated away and clients still holding
    the pre-flip table run a write batch: the flipped partition's lanes are
    refused by the old owner (``stale_route`` aborts in round 0), pay ONE
    refresh read in round 1, and commit; valid routes commit in round 0
    untouched — the abort-cause mix and rounds-to-converge are printed and
    gated.
  * ``leave``     — graceful exit: ``drain_plan`` + ``migrate_partition``
    per owned partition (source-lock -> copy -> epoch flip), then
    ``leave_node``; reports migration wire bytes.
  * ``join``      — a node (re)joins and one partition is migrated onto it;
    same accounting.

    PYTHONPATH=src python benchmarks/membership_churn.py [--smoke]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_line, make_tx_workload, time_jit
from repro.core import placement as pl
from repro.core import telemetry as T
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.replication import ReplicaConfig
from repro.core.transport import SimTransport

N_NODES, LANES, MAX_ROUNDS = 4, 8, 2


def _cluster(seed=5):
    """The bench-gate cluster + workload (common.make_tx_workload) so the
    steady-state schedule here and the gated one can never diverge."""
    cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(N_NODES)
    state = ht.init_cluster_state(cfg)
    state, rk, wk, wv = make_tx_workload(t, cfg, layout, state, lanes=LANES,
                                         n_keys=64, seed=seed)
    return cfg, layout, t, state, rk, wk, wv


def steady_state():
    """f=1 workload with vs without a placement table: identical rounds."""
    cfg, layout, t, state, rk, wk, wv = _cluster()
    rep = ReplicaConfig(N_NODES, 1)
    pcfg = pl.PlacementConfig(N_NODES, f=1)
    table = pl.initial_table(pcfg)

    run_rep = jax.jit(lambda st: txl.tx_loop(
        t, st, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=MAX_ROUNDS, rep=rep))
    run_pl = jax.jit(lambda st: txl.tx_loop(
        t, st, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=MAX_ROUNDS, rep=rep, ptable=table, pcfg=pcfg))
    (_, _, res0), _ = time_jit(run_rep, state)
    (_, _, res1), secs = time_jit(run_pl, state)

    rt0, rt1 = float(res0.round_trips), float(res1.round_trips)
    assert rt1 == rt0, \
        f"identity placement table must add ZERO exchange rounds ({rt0} -> {rt1})"
    assert float(jnp.sum(res1.round_abort_stale)) == 0.0, \
        "no stale-route aborts at a stable epoch"
    np.testing.assert_array_equal(np.asarray(res0.committed),
                                  np.asarray(res1.committed))
    return dict(
        round_trips_stable=rt1,
        round_trips_rep_only=rt0,
        commit_rate_stable=round(float(jnp.mean(res1.committed)), 4),
        wire_bytes_stable=round(
            float(res1.metrics.wire.total_bytes) / (N_NODES * LANES), 2),
        secs=secs,
    )


def refresh_cost():
    """ONE one-sided read per table refresh; gated-off refresh = zero wire."""
    cfg, layout, t, state, *_ = _cluster()
    pcfg = pl.PlacementConfig(N_NODES, f=1)
    table = pl.initial_table(pcfg)
    _, stats = pl.refresh_table(t, state, layout, pcfg, table)
    _, s_off = pl.refresh_table(t, state, layout, pcfg, table,
                                enabled=jnp.asarray(False))
    assert float(s_off.round_trips) == 0.0 and float(s_off.ops) == 0.0, \
        "a gated-off refresh must issue nothing"
    return dict(round_trips=float(stats.round_trips),
                bytes=float(stats.total_bytes))


def _populated_placement_cluster(seed=5):
    """Cluster populated THROUGH the replicated commit path at f=1 with
    placement routing (write-only lanes; the churn events below reuse it)."""
    cfg, layout, t, state, rk, wk, wv = _cluster(seed=seed)
    rep = ReplicaConfig(N_NODES, 1)
    pcfg = pl.PlacementConfig(N_NODES, f=1)
    table = pl.initial_table(pcfg)
    no_reads = jnp.zeros((N_NODES, LANES, 0, 2), jnp.uint32)
    state, _, res = txl.tx_loop(
        t, state, cfg, layout, read_keys=no_reads, write_keys=wk,
        write_values=wv, max_rounds=4, rep=rep, ptable=table, pcfg=pcfg)
    assert bool(np.asarray(res.committed).all())
    return cfg, layout, t, state, wk, wv, rep, pcfg, table


def kill_event():
    """Fail a node at f=1: repair_plan + rereplicate restore the copy count;
    report the recovery traffic (the dead node's partitions streamed from
    surviving copies to fresh backups)."""
    cfg, layout, t, state, wk, wv, rep, pcfg, table = \
        _populated_placement_cluster()
    dead = 1
    table = pl.kill_node(pcfg, table, dead)
    table, transfers = pl.repair_plan(pcfg, table)
    state = dict(state,
                 arena=state["arena"].at[dead].set(jnp.uint32(0xDEAD)))
    state = pl.install_local(state, layout, pcfg, table,
                             nodes=[n for n in range(N_NODES) if n != dead])
    state, s_rr = pl.rereplicate(t, state, cfg, layout, pcfg, transfers)
    return dict(rereplication_bytes=round(float(s_rr.total_bytes), 2),
                transfers=len(transfers))


def stale_mix():
    """The abort-cause mix for clients caught by an epoch flip: partition 0
    is migrated away, stale clients' partition-0 lanes are refused by the
    OLD owner (ST_WRONG_EPOCH, a node cannot mutate a partition it lost),
    refresh the table for ONE one-sided read, and commit on the retry.
    Lanes whose routes stayed valid commit in round 0, untouched."""
    cfg, layout, t, state, wk, wv, rep, pcfg, table = \
        _populated_placement_cluster()
    stale_table = table                       # the pre-flip client view
    table, state, _, ok = pl.migrate_partition(
        t, state, cfg, layout, pcfg, table, 0, 3)
    assert ok, "uncontended migration must succeed"

    wk2 = wk ^ jnp.uint32(0x5DEECE66)
    no_reads = jnp.zeros((N_NODES, LANES, 0, 2), jnp.uint32)
    _, _, res = txl.tx_loop(
        t, state, cfg, layout, read_keys=no_reads, write_keys=wk2,
        write_values=wv, max_rounds=3, rep=rep, ptable=stale_table,
        pcfg=pcfg)
    stale_r = np.asarray(res.round_abort_stale)
    assert bool(np.asarray(res.committed).all()), \
        "stale clients must converge after one refresh"
    assert int(stale_r[0]) > 0, \
        "the flipped partition's lanes must abort stale_route in round 0"
    assert int(stale_r[1:].sum()) == 0, \
        "one refresh resolves every stale route"
    converge = int(np.asarray(res.commit_round).max()) + 1
    return dict(
        abort_stale_round0=int(stale_r[0]),
        abort_lock=int(np.asarray(res.round_abort_lock).sum()),
        abort_validate=int(np.asarray(res.round_abort_validate).sum()),
        abort_overflow=int(np.asarray(res.round_abort_overflow).sum()),
        stale_rounds_to_converge=converge,
        stale_round_trips=float(res.round_trips),
    )


def leave_gracefully():
    """drain_plan + migrate_partition each owned partition, then leave."""
    cfg, layout, t, state, wk, wv, rep, pcfg, table = \
        _populated_placement_cluster(seed=6)
    node = 2
    plan = pl.drain_plan(pcfg, table, node)
    total = 0.0
    for part, dst in plan:
        table, state, stats, ok = pl.migrate_partition(
            t, state, cfg, layout, pcfg, table, part, dst)
        assert ok, f"uncontended migration of part {part} must succeed"
        total += float(stats.total_bytes)
    table = pl.leave_node(pcfg, table, node)
    assert int(np.asarray(table.copies)[:, 0].tolist().count(node)) == 0, \
        "a drained node owns nothing"
    return dict(migrations=len(plan), migration_bytes=round(total, 2),
                epoch=int(table.epoch))


def join_and_rebalance():
    """A node rejoins; one partition is migrated onto it."""
    cfg, layout, t, state, wk, wv, rep, pcfg, table = \
        _populated_placement_cluster(seed=7)
    node = 3
    table = pl.leave_node(pcfg, table, node)
    table, transfers = pl.repair_plan(pcfg, table)
    state = pl.install_local(state, layout, pcfg, table)
    state, _ = pl.rereplicate(t, state, cfg, layout, pcfg, transfers)

    table = pl.join_node(pcfg, table, node)
    part = node                                    # give it its ring slot back
    table, state, stats, ok = pl.migrate_partition(
        t, state, cfg, layout, pcfg, table, part, node)
    assert ok and int(np.asarray(table.copies)[part, 0]) == node
    return dict(migration_bytes=round(float(stats.total_bytes), 2),
                epoch=int(table.epoch))


def fill_registry(reg: T.MetricsRegistry) -> T.MetricsRegistry:
    """Publish the membership bill to a MetricsRegistry (the metrics.json
    surface): refresh reads issued, re-replication bytes, the stale-retry
    schedule and the epoch-stable baseline.  ``gate_numbers`` derives the
    bench-gate keys FROM this registry, so the gated numbers and the
    published ones can never diverge."""
    ss = steady_state()
    rf = refresh_cost()
    kl = kill_event()
    sm = stale_mix()
    reg.set("membership.round_trips_stable", ss["round_trips_stable"])
    reg.set("membership.commit_rate_stable", ss["commit_rate_stable"])
    reg.set("membership.wire_bytes_stable", ss["wire_bytes_stable"])
    reg.incr("membership.refresh_reads_issued", rf["round_trips"])
    reg.set("membership.refresh_round_trips", rf["round_trips"])
    reg.set("membership.refresh_bytes", rf["bytes"])
    reg.set("membership.rereplication_bytes", kl["rereplication_bytes"])
    reg.incr("membership.rereplication_transfers", kl["transfers"])
    reg.set("membership.stale_round_trips", sm["stale_round_trips"])
    reg.incr("membership.stale_aborts_round0", sm["abort_stale_round0"])
    reg.set("membership.stale_rounds_to_converge",
            sm["stale_rounds_to_converge"])
    return reg


def gate_numbers(registry: T.MetricsRegistry | None = None):
    """Deterministic membership numbers for bench_gate.py, derived from the
    ``fill_registry`` counters.  Collect-time structural asserts (schedule
    equality, one-read refresh, single-round stale convergence) fire BEFORE
    any baseline comparison."""
    reg = fill_registry(registry if registry is not None
                        else T.MetricsRegistry())
    assert reg.get("membership.refresh_round_trips") == 1.0, \
        "a table refresh is ONE one-sided read"
    assert reg.get("membership.stale_rounds_to_converge") <= 2.0, \
        "one refresh must resolve every stale route"
    return {
        "round_trips_stable": reg.get("membership.round_trips_stable"),
        "commit_rate_stable": reg.get("membership.commit_rate_stable"),
        "refresh_round_trips": reg.get("membership.refresh_round_trips"),
        "rereplication_bytes": reg.get("membership.rereplication_bytes"),
        "stale_round_trips": reg.get("membership.stale_round_trips"),
    }


def main(smoke=False):
    ss = steady_state()
    csv_line("membership/steady", ss["secs"] * 1e6,
             f"rt={ss['round_trips_stable']};"
             f"rt_rep_only={ss['round_trips_rep_only']};"
             f"commit={ss['commit_rate_stable']};"
             f"bytes_tx={ss['wire_bytes_stable']}")
    rf = refresh_cost()
    csv_line("membership/refresh", 0.0,
             f"round_trips={rf['round_trips']};bytes={rf['bytes']}")
    kl = kill_event()
    csv_line("membership/kill", 0.0,
             f"rereplication_bytes={kl['rereplication_bytes']};"
             f"transfers={kl['transfers']}")
    sm = stale_mix()
    csv_line("membership/stale_mix", 0.0,
             f"abort_stale_r0={sm['abort_stale_round0']};"
             f"abort_lock={sm['abort_lock']};"
             f"abort_validate={sm['abort_validate']};"
             f"abort_overflow={sm['abort_overflow']};"
             f"rounds_to_converge={sm['stale_rounds_to_converge']}")
    lv = leave_gracefully()
    csv_line("membership/leave", 0.0,
             f"migrations={lv['migrations']};"
             f"bytes={lv['migration_bytes']};epoch={lv['epoch']}")
    if not smoke:
        jn = join_and_rebalance()
        csv_line("membership/join", 0.0,
                 f"bytes={jn['migration_bytes']};epoch={jn['epoch']}")


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
