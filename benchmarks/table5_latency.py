"""Table 5: unloaded round-trip latencies.

Modeled from protocol structure (hops x base RT + wire + per-system terms)
with the calibrated fabric; the CPU-sim per-op wall time is reported for
transparency.  Paper (CX4-IB): Storm RR 1.8us, Storm RPC 2.7us, eRPC 2.7us,
FaRM 2.1us, LITE 5.8us.
"""
from __future__ import annotations

from common import ModelFabric, csv_line
from repro.core import slots as sl

FAB = ModelFabric()
PAPER = {"storm_rr": 1.8, "storm_rpc": 2.7, "erpc": 2.7, "farm": 2.1,
         "lite": 5.8}


def modeled_latencies():
    wire_1kb = 8 * sl.SLOT_BYTES * 8 / (FAB.link_gbps * 1e3)
    return {
        "storm_rr": FAB.rt_onesided_us,
        "storm_rpc": FAB.rt_rpc_us,
        "erpc": FAB.rt_rpc_us + 2 * FAB.recv_post_us,
        "farm": FAB.rt_onesided_us + wire_1kb
                + FAB.dma_seg_us_per_kb * (8 * sl.SLOT_BYTES / 1024),
        "lite": FAB.rt_rpc_us + 2 * FAB.syscall_us,
    }


def modeled_tx_latencies():
    """Unloaded OCC transaction latency = sum of its exchange rounds' RTs.

    per-phase 5-round: read(1S) + fallback(RPC) + lock(RPC) + validate(1S)
                       + commit(RPC)
    fused 4-round:     read(1S) + [fallback∥lock∥validate-hits](RPC)
                       + validate-misses(1S) + commit(RPC)
    fused 3-round:     read(1S) + [lock∥validate](RPC) + commit(RPC)
                       (every read-set lookup satisfied one-sided)
    """
    rd, rpc = FAB.rt_onesided_us, FAB.rt_rpc_us
    return {
        "tx_5round": rd + rpc + rpc + rd + rpc,
        "tx_fused4": rd + rpc + rd + rpc,
        "tx_fused3": rd + rpc + rpc,
    }


def main():
    lat = modeled_latencies()
    for name, us in lat.items():
        csv_line(f"table5/{name}", us,
                 f"modeled_rt_us={us:.2f};paper_rt_us={PAPER[name]:.2f}")
    # relative ordering must match the paper
    assert lat["storm_rr"] < lat["farm"] < lat["storm_rpc"] <= lat["erpc"] < lat["lite"]
    tx = modeled_tx_latencies()
    for name, us in tx.items():
        csv_line(f"table5/{name}", us, f"modeled_tx_us={us:.2f}")
    # fusing provably-independent phases must strictly cut modeled latency
    assert tx["tx_fused3"] < tx["tx_fused4"] < tx["tx_5round"]
    return lat


if __name__ == "__main__":
    main()
