"""§6.2.5: physical segments vs paged addressing.

Same owner-side data movement; the only difference is address translation:
flat (physical segment: one bounds check) vs paged (4KB pages: every access
walks the page table — the MTT emulation).  We isolate the OWNER-side
translation+gather path (where the NIC's MTT walk lives), measure its CPU
wall time, and verify STRUCTURALLY that the paged path executes an extra
dependent gather per read (the mechanism behind the paper's 32% win for
physical segments — on a real NIC that dependent load is a PCIe round trip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_line, time_jit
from repro.core import regions as rg

ARENA_WORDS = 1 << 22          # 16 MiB arena
LANES = 1 << 15                # 32k outstanding reads
READ_WORDS = 32                # one 128B slot
PAGE_WORDS = 1024              # 4 KiB pages


def gather_count(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(str(eqn.primitive) == "gather" for eqn in jaxpr.eqns)


def main():
    rng = np.random.RandomState(1)
    arena = jnp.arange(ARENA_WORDS, dtype=jnp.uint32)
    offs = jnp.asarray(
        rng.randint(0, ARENA_WORDS - READ_WORDS, LANES), jnp.uint32)
    n_pages = ARENA_WORDS // PAGE_WORDS
    page_table = jnp.asarray(rng.permutation(n_pages), jnp.uint32)
    paged = rg.AddressMode(kind="paged", page_words=PAGE_WORDS)

    flat_fn = jax.jit(lambda a, o: rg.arena_read(a, o, READ_WORDS))
    paged_fn = jax.jit(lambda a, o, pt: rg.arena_read(
        a, o, READ_WORDS, mode=paged, page_table=pt))

    out_f, dt_f = time_jit(flat_fn, arena, offs, iters=5)
    out_p, dt_p = time_jit(paged_fn, arena, offs, page_table, iters=5)

    # correctness: flat returns the arange pattern; paged honours the permuted
    # page table (logical page p lives at physical page page_table[p])
    np.testing.assert_array_equal(
        np.asarray(out_f[0]),
        np.arange(int(offs[0]), int(offs[0]) + READ_WORDS))
    o0 = int(offs[0])
    logical = np.arange(o0, o0 + READ_WORDS)
    phys = (np.asarray(page_table)[logical // PAGE_WORDS] * PAGE_WORDS
            + logical % PAGE_WORDS)
    np.testing.assert_array_equal(np.asarray(out_p[0]), phys.astype(np.uint32))

    csv_line("physseg/flat", dt_f / LANES * 1e6, f"read_words={READ_WORDS}")
    csv_line("physseg/paged", dt_p / LANES * 1e6, f"read_words={READ_WORDS}")
    ratio = dt_p / dt_f
    g_flat = gather_count(lambda a, o: rg.arena_read(a, o, READ_WORDS),
                          arena, offs)
    g_paged = gather_count(
        lambda a, o: rg.arena_read(a, o, READ_WORDS, mode=paged,
                                   page_table=page_table), arena, offs)
    print(f"# paged/flat wall-time ratio: {ratio:.2f}x on CPU "
          f"(paper: +32% for physical segments on a real NIC, where the "
          f"page walk is a dependent PCIe load)")
    print(f"# gathers per read: flat={g_flat} paged={g_paged}")
    assert g_paged > g_flat, "paged path must add a page-table gather"


if __name__ == "__main__":
    main()
