"""Bench gate: machine-readable perf snapshot + CI regression gate.

Collects the protocol's headline numbers into a JSON snapshot:

  * ``round_trips`` / ``rt_round`` — exchange rounds issued by a fixed,
    deterministic fused OCC workload (the quantity PR 2's fusion cut 5 -> 3-4;
    ANY increase is a regression);
  * ``tx_latency_us`` — the modeled unloaded transaction latencies of the
    three schedules (table5);
  * ``mops_node`` — modeled Mops/node per connection mode at 32 and 96
    emulated nodes, 20 threads (the core/nic model conn_scaling sweeps);
  * ``replication`` — the SAME workload at replication factor f=1:
    ``round_trips_f1`` (must equal the f=0 round trips — backup writes ride
    the commit fused round, and any increase fails the gate),
    ``wire_bytes_tx_f1`` and modeled Mtx/node per connection mode at 96
    emulated nodes, so a PR can't silently make replication more expensive;
  * ``ordered`` — the ordered B-link index (range_scan.py's deterministic
    workload): ``scan_round_trips`` (the one-sided fast-path scan schedule —
    MUST stay equal to the point-lookup schedule's rounds; any increase
    fails), commit rate and modeled Mtx/node at 32 emulated nodes for the
    scan-heavy mix (5% threshold);
  * ``telemetry`` — the flight recorder (core/telemetry.py): the traced
    TATP smoke's committed-latency percentiles (``latency_us_p50`` /
    ``latency_us_p99``, 5% threshold) and its commit rate; collect()
    additionally asserts, BEFORE any comparison, that running the gate
    workload with the recorder ON is bit-identical (commit mask, wire ops /
    bytes, round trips) to running it with ``telemetry=None`` — the
    recorder's zero-cost-when-disabled AND read-only-when-enabled invariants;
  * ``membership`` — the placement subsystem (membership_churn.py):
    ``round_trips_stable`` (the f=1 workload routed through an epoch-stable
    placement table — MUST equal the rep-only schedule; any increase fails),
    ``refresh_round_trips`` (a table refresh is ONE one-sided read),
    ``stale_round_trips`` (the abort-refresh-retry schedule after an epoch
    flip) and ``rereplication_bytes`` (recovery traffic for one node death
    at f=1, 5% threshold).

CI runs this twice: ``--out BENCH_PR.json`` on the PR (uploaded as an
artifact) and compares against the checked-in ``BENCH_BASELINE.json``:
>5% modeled-latency growth, >5% modeled-throughput drop, or any
round-trips increase fails the job.  ``--write-baseline`` refreshes the
baseline after an intentional protocol change.

    PYTHONPATH=src python benchmarks/bench_gate.py --out BENCH_PR.json \
        --baseline benchmarks/BENCH_BASELINE.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

LAT_TOL = 1.05    # >5% modeled latency growth fails
TPUT_TOL = 0.95   # >5% modeled throughput drop fails


def _tx_smoke():
    """Deterministic fused tx_loop workload; returns wire-level counts."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from common import make_tx_workload
    from repro.core import txloop as txl
    from repro.core.datastructs import hashtable as ht
    from repro.core.transport import SimTransport

    n_nodes, lanes, max_rounds = 4, 8, 2
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    state, rk, wk, wv = make_tx_workload(t, cfg, layout, state, lanes=lanes,
                                         n_keys=64, seed=5)
    _, _, res = jax.jit(lambda st: txl.tx_loop(
        t, st, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=max_rounds))(state)
    rounds_attempted = int((np.asarray(res.round_attempts) > 0).sum())

    # the flight recorder must only ever READ protocol values: the same
    # workload with telemetry enabled is bit-identical (collect-time assert,
    # fires before any baseline comparison)
    from repro.core import telemetry as T
    _, _, res_t, tel = jax.jit(lambda st: txl.tx_loop(
        t, st, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=max_rounds, telemetry=T.TelemetryConfig()))(state)
    assert np.array_equal(np.asarray(res.committed),
                          np.asarray(res_t.committed)) \
        and float(res_t.round_trips) == float(res.round_trips) \
        and float(res_t.metrics.wire.total_bytes) == \
        float(res.metrics.wire.total_bytes), \
        "telemetry=on must be bit-identical to telemetry=None"
    assert int(tel.trace.dropped) == 0 and int(tel.trace.n) > 0, \
        "the gate workload must fit the default trace buffer"

    # the same workload with one backup copy per record (f=1)
    from repro.core.replication import ReplicaConfig
    rep = ReplicaConfig(n_nodes, 1)
    _, _, res1 = jax.jit(lambda st: txl.tx_loop(
        t, st, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=max_rounds, rep=rep))(state)
    n_tx = n_nodes * lanes
    return dict(
        round_trips=float(res.round_trips),
        rt_round=float(res.round_trips) / max(rounds_attempted, 1),
        commit_rate=float(jnp.mean(res.committed)),
        wire_bytes_tx=float(res.metrics.wire.total_bytes) / n_tx,
        f1=dict(
            round_trips=float(res1.round_trips),
            bytes_tx=float(res1.metrics.wire.total_bytes) / n_tx,
            ops_tx=float(res1.metrics.wire.ops) / n_tx,
            commit_rate=float(jnp.mean(res1.committed)),
        ),
    )


def collect() -> dict:
    import conn_scaling
    import membership_churn
    import range_scan
    import replication_cost
    import table5_latency
    from repro.core import nic as qn

    mops = {}
    for mode in qn.MODES:
        mops[mode] = {str(m): round(conn_scaling.modeled(m, 20, mode)[0], 4)
                      for m in (32, 96)}
    tx = _tx_smoke()
    f1 = tx["f1"]
    # structural invariant, checked at collect time so a PR that un-fuses the
    # backup writes fails BEFORE any baseline comparison
    assert f1["round_trips"] == tx["round_trips"], \
        f"f=1 must add zero exchange rounds ({f1['round_trips']} vs " \
        f"{tx['round_trips']})"
    mops_f1 = {mode: round(replication_cost.modeled_mtx(
        dict(bytes_tx=f1["bytes_tx"], ops_tx=f1["ops_tx"]), 1,
        qn.ConnTable(n_nodes=96, threads=20, mode=mode)), 4)
        for mode in qn.MODES}
    import fig6_tatp
    treg, _ = fig6_tatp.traced_smoke()
    out = {
        "round_trips": tx["round_trips"],
        "rt_round": round(tx["rt_round"], 4),
        "commit_rate": round(tx["commit_rate"], 4),
        "wire_bytes_tx": round(tx["wire_bytes_tx"], 2),
        "tx_latency_us": {k: round(v, 4)
                          for k, v in table5_latency.modeled_tx_latencies().items()},
        "mops_node": mops,
        "replication": {
            "round_trips_f1": f1["round_trips"],
            "wire_bytes_tx_f1": round(f1["bytes_tx"], 2),
            "commit_rate_f1": round(f1["commit_rate"], 4),
            "mops_node_f1": mops_f1,
        },
        # range_scan.gate_numbers() asserts, BEFORE any baseline comparison,
        # that the fast-path scan costs exactly the point-lookup schedule
        # and that f=1 adds zero rounds to it
        "ordered": range_scan.gate_numbers(),
        # the traced TATP smoke's committed-latency percentiles — the
        # modeled latency distribution the flight recorder accumulates
        # per lane (5% growth fails); trace health is asserted above
        "telemetry": {
            "latency_us_p50":
                round(treg.get("tatp.latency_us.committed.p50"), 4),
            "latency_us_p99":
                round(treg.get("tatp.latency_us.committed.p99"), 4),
            "commit_rate": round(treg.get("tatp.commit_rate"), 4),
        },
        # membership_churn.gate_numbers() asserts that the epoch-stable
        # placement-routed schedule equals the rep-only one and that a table
        # refresh is ONE one-sided read; the snapshot then pins the recovery
        # traffic and the stale-retry schedule
        "membership": membership_churn.gate_numbers(),
    }
    assert out["membership"]["round_trips_stable"] == out["round_trips"], \
        f"epoch-stable placement routing must cost the rep-only schedule " \
        f"({out['membership']['round_trips_stable']} vs " \
        f"{out['round_trips']} round trips)"
    return out


def compare(pr: dict, base: dict) -> list[str]:
    """Return the list of regressions of `pr` vs `base` (empty = gate green)."""
    fails = []
    if pr["round_trips"] > base["round_trips"]:
        fails.append(f"round_trips increased: {base['round_trips']} -> "
                     f"{pr['round_trips']} (any increase fails)")
    for k, b in base["tx_latency_us"].items():
        p = pr["tx_latency_us"].get(k)
        if p is None or p > b * LAT_TOL:
            fails.append(f"tx_latency_us.{k} regressed: {b} -> {p} "
                         f"(>{LAT_TOL:.0%} of baseline)")
    for mode, per_m in base["mops_node"].items():
        for m, b in per_m.items():
            p = pr["mops_node"].get(mode, {}).get(m)
            if p is None or p < b * TPUT_TOL:
                fails.append(f"mops_node.{mode}.{m} regressed: {b} -> {p} "
                             f"(<{TPUT_TOL:.0%} of baseline)")
    rb = base.get("replication")
    if rb is not None:
        rp = pr.get("replication") or {}
        if rp.get("round_trips_f1") is None or \
                rp["round_trips_f1"] > rb["round_trips_f1"]:
            fails.append(f"replication.round_trips_f1 increased: "
                         f"{rb['round_trips_f1']} -> "
                         f"{rp.get('round_trips_f1')} (any increase fails)")
        p = rp.get("commit_rate_f1")
        if p is None or p < rb["commit_rate_f1"]:
            fails.append(f"replication.commit_rate_f1 dropped: "
                         f"{rb['commit_rate_f1']} -> {p} (any drop fails: "
                         f"the gate workload is deterministic)")
        p = rp.get("wire_bytes_tx_f1")
        if p is None or p > rb["wire_bytes_tx_f1"] * LAT_TOL:
            fails.append(f"replication.wire_bytes_tx_f1 regressed: "
                         f"{rb['wire_bytes_tx_f1']} -> {p} "
                         f"(>{LAT_TOL:.0%} of baseline)")
        for mode, b in rb["mops_node_f1"].items():
            p = rp.get("mops_node_f1", {}).get(mode)
            if p is None or p < b * TPUT_TOL:
                fails.append(f"replication.mops_node_f1.{mode} regressed: "
                             f"{b} -> {p} (<{TPUT_TOL:.0%} of baseline)")
    ob = base.get("ordered")
    if ob is not None:
        op = pr.get("ordered") or {}
        p = op.get("scan_round_trips")
        if p is None or p > ob["scan_round_trips"]:
            fails.append(f"ordered.scan_round_trips increased: "
                         f"{ob['scan_round_trips']} -> {p} "
                         f"(any increase fails: the fast-path scan must "
                         f"cost the point-lookup schedule)")
        p = op.get("commit_rate")
        if p is None or p < ob["commit_rate"]:
            fails.append(f"ordered.commit_rate dropped: {ob['commit_rate']} "
                         f"-> {p} (any drop fails: deterministic workload)")
        p = op.get("mops_node_32")
        if p is None or p < ob["mops_node_32"] * TPUT_TOL:
            fails.append(f"ordered.mops_node_32 regressed: "
                         f"{ob['mops_node_32']} -> {p} "
                         f"(<{TPUT_TOL:.0%} of baseline)")
    tb = base.get("telemetry")
    if tb is not None:
        tp = pr.get("telemetry") or {}
        for k in ("latency_us_p50", "latency_us_p99"):
            p = tp.get(k)
            if p is None or p > tb[k] * LAT_TOL:
                fails.append(f"telemetry.{k} regressed: {tb[k]} -> {p} "
                             f"(>{LAT_TOL:.0%} of baseline)")
        p = tp.get("commit_rate")
        if p is None or p < tb["commit_rate"]:
            fails.append(f"telemetry.commit_rate dropped: "
                         f"{tb['commit_rate']} -> {p} (any drop fails: "
                         f"deterministic workload)")
    mb = base.get("membership")
    if mb is not None:
        mp = pr.get("membership") or {}
        for k in ("round_trips_stable", "refresh_round_trips",
                  "stale_round_trips"):
            p = mp.get(k)
            if p is None or p > mb[k]:
                fails.append(f"membership.{k} increased: {mb[k]} -> {p} "
                             f"(any increase fails: the epoch-stable/"
                             f"refresh/stale-retry schedules are pinned)")
        p = mp.get("commit_rate_stable")
        if p is None or p < mb["commit_rate_stable"]:
            fails.append(f"membership.commit_rate_stable dropped: "
                         f"{mb['commit_rate_stable']} -> {p} "
                         f"(any drop fails: deterministic workload)")
        p = mp.get("rereplication_bytes")
        if p is None or p > mb["rereplication_bytes"] * LAT_TOL:
            fails.append(f"membership.rereplication_bytes regressed: "
                         f"{mb['rereplication_bytes']} -> {p} "
                         f"(>{LAT_TOL:.0%} of baseline)")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR.json")
    ap.add_argument("--baseline",
                    default=str(pathlib.Path(__file__).parent / "BENCH_BASELINE.json"))
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the snapshot to --baseline instead of gating")
    args = ap.parse_args()

    snap = collect()
    out = pathlib.Path(args.baseline if args.write_baseline else args.out)
    out.write_text(json.dumps(snap, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {out}")
    if args.write_baseline:
        return 0

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        # the baseline is checked in: absence means it was deleted/renamed,
        # and silently skipping would disable the gate for every later PR
        print(f"BENCH-GATE FAIL: no baseline at {base_path} "
              f"(seed one with --write-baseline)")
        return 1
    fails = compare(snap, json.loads(base_path.read_text()))
    for f in fails:
        print(f"BENCH-GATE FAIL: {f}")
    if not fails:
        print("# bench gate green: no regression vs baseline")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
