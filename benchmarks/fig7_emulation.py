"""Figure 7: beyond rack scale — NIC-cache pressure from connection state.

The paper emulates 32..128 nodes by allocating the real connection count and
buffers.  We reproduce the mechanism through the core connection-state
subsystem (``repro.core.nic``): a :class:`~repro.core.nic.ConnTable` models
the per-node QP state (2·m·t sibling-thread RC), the NIC-cache hit rate and
the per-op PCIe penalty of evicted state; this benchmark is a THIN SWEEP over
that shared model — every calibration constant lives in ``core/nic.py``
(single source of truth), and ``benchmarks/conn_scaling.py`` sweeps the same
model across all three connection modes.

Calibrated behaviour (see NicModel): the 20-thread RC curve drops 1.57x at
96 nodes (the paper's number) while the 10-thread curve stays flat to 128;
both behaviours EMERGE from the model at every other sweep point.
"""
from __future__ import annotations

from common import csv_line
from conn_scaling import modeled


def main():
    base20, _ = modeled(32, 20)
    for t in (20, 10):
        for m in (32, 64, 96, 128):
            mops, ct = modeled(m, t)
            csv_line(f"fig7/t{t}/m{m}", 1.0 / mops,
                     f"modeled_Mops_node={mops:.2f};"
                     f"qp_cache_hit={ct.cache_hit:.2f};"
                     f"conns_node={ct.conns_per_node}")
    drop96 = base20 / modeled(96, 20)[0]
    flat128 = modeled(32, 10)[0] / modeled(128, 10)[0]
    print(f"# 20-thread drop at 96 nodes: {drop96:.2f}x (paper 1.57x); "
          f"10-thread 32->128 ratio: {flat128:.2f}x (paper ~1.0x)")
    assert drop96 > 1.3, "20-thread curve must degrade beyond 64 nodes"
    assert flat128 < 1.15, "10-thread curve must stay flat to 128 nodes"


if __name__ == "__main__":
    main()
