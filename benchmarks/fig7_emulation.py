"""Figure 7: beyond rack scale — NIC-cache pressure from connection state.

The paper emulates 32..128 nodes by allocating the real connection count and
buffers.  We reproduce the mechanism with (a) the protocol simulator at the
emulated node counts for wire metrics, and (b) an explicit NIC-cache model:

    conns/node      = 2 * m * t                (sibling-thread RC, §3.4)
    qp_state        = conns * 375 B            (§2.1)
    hit             = min(1, qp_cache_eff / qp_state)
    per-op penalty  = (1 - hit) * pcie_us      (DMA fetch of evicted state)

Calibration (documented): qp_cache_eff = 1 MiB of the ~2 MiB NIC cache is
available for QP state (the rest holds WQE/MTT/MPT), pcie_us = 0.15 —
chosen so the 20-thread curve drops ~1.57x at 96 nodes (the paper's number)
while the 10-thread curve stays flat to 128; both behaviours then EMERGE
from the model at every other point.
"""
from __future__ import annotations

from common import ModelFabric, csv_line, modeled_throughput_per_node

FAB = ModelFabric()
QP_BYTES = 375
QP_CACHE_EFF = 1.0 * 1024 * 1024
PCIE_US = 0.15


def modeled(m_nodes: int, threads: int):
    conns = 2 * m_nodes * threads
    state = conns * QP_BYTES
    hit = min(1.0, QP_CACHE_EFF / max(state, 1))
    penalty = (1 - hit) * PCIE_US
    mops = modeled_throughput_per_node(
        reads_per_op=1.0, rpcs_per_op=0.0, wire_bytes_per_op=140,
        lanes=32, extra_cpu_us_per_op=penalty)
    return mops, hit


def main():
    base20, _ = modeled(32, 20)
    for t in (20, 10):
        for m in (32, 64, 96, 128):
            mops, hit = modeled(m, t)
            csv_line(f"fig7/t{t}/m{m}", 1.0 / mops,
                     f"modeled_Mops_node={mops:.2f};qp_cache_hit={hit:.2f};"
                     f"conns_node={2*m*t}")
    drop96 = base20 / modeled(96, 20)[0]
    flat128 = modeled(32, 10)[0] / modeled(128, 10)[0]
    print(f"# 20-thread drop at 96 nodes: {drop96:.2f}x (paper 1.57x); "
          f"10-thread 32->128 ratio: {flat128:.2f}x (paper ~1.0x)")
    assert drop96 > 1.3, "20-thread curve must degrade beyond 64 nodes"
    assert flat128 < 1.15, "10-thread curve must stay flat to 128 nodes"


if __name__ == "__main__":
    main()
