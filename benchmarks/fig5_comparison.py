"""Figure 5: Storm vs eRPC vs Lock-free_FaRM vs Async_LITE on key-value
lookups.

Every system runs on the SAME simulated protocol core; what differs is
exactly what differed in the paper:
  * Storm(oversub)  — one-two-sided, fine-grained 128B reads
  * eRPC            — two-sided only (send/recv semantics): every lookup is
                      an RPC + per-message receive posting + app-level
                      congestion control; a no-CC variant drops the CC term
  * Lock-free_FaRM  — one-sided only with 8x larger reads (width-8 buckets,
                      hopscotch-style: item guaranteed in the neighborhood)
  * Async_LITE      — RPC-only through the kernel: adds the syscall/locking
                      serialization term

Modeled per-op costs use the calibrated ModelFabric (EXPERIMENTS.md §Fig5);
protocol metrics (bytes, fractions) come from the simulator run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import (ModelFabric, csv_line, modeled_throughput_per_node,
                    populate, time_jit)
from repro.core import hybrid as hy
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

LANES = 32
KEYS_PER_NODE = 192
FAB = ModelFabric()


def run_system(name, n_nodes, *, width: int, use_onesided: bool,
               extra_cpu: float, oversub: bool = True, lanes=LANES):
    n_buckets = 1024 if oversub else 128
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=n_buckets,
                             bucket_width=width, n_overflow=KEYS_PER_NODE,
                             max_chain=12)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    state, (klo, khi) = populate(cfg, layout, t, state, KEYS_PER_NODE)

    rng = np.random.RandomState(11)
    src = rng.randint(0, n_nodes, (n_nodes, lanes))
    idx = rng.randint(0, KEYS_PER_NODE, (n_nodes, lanes))
    kl = jnp.asarray(np.asarray(klo)[src, idx])
    kh = jnp.asarray(np.asarray(khi)[src, idx])

    @jax.jit
    def round_fn(state):
        st, _, found, val, ver, node, sidx, _, m = hy.hybrid_lookup(
            t, state, kl, kh, cfg, layout, use_onesided=use_onesided)
        return st, found, m

    (state, found, m), dt = time_jit(round_fn, state)
    assert bool(found.all())
    ops = n_nodes * lanes
    rpc_frac = float(m.rpc_fallback) / float(m.total)
    wire_b = float(m.wire.total_bytes) / ops
    reads_per_op = 1.0 if use_onesided else 0.0
    dma = (width * sl.SLOT_BYTES / 1024.0) * FAB.dma_seg_us_per_kb \
        if (use_onesided and width > 1) else 0.0
    mops = modeled_throughput_per_node(
        reads_per_op=reads_per_op, rpcs_per_op=rpc_frac,
        wire_bytes_per_op=wire_b, lanes=lanes,
        extra_cpu_us_per_op=extra_cpu + dma)
    csv_line(f"fig5/{name}/n{n_nodes}", dt / ops * 1e6,
             f"modeled_Mops_node={mops:.2f};rpc_frac={rpc_frac:.2f};"
             f"bytes_op={wire_b:.0f}")
    return mops


def main(node_counts=(4, 8, 16)):
    res = {}
    for n in node_counts:
        storm = run_system("storm_oversub", n, width=1, use_onesided=True,
                           extra_cpu=0.0)
        erpc = run_system("erpc", n, width=1, use_onesided=False,
                          extra_cpu=2 * FAB.recv_post_us + FAB.app_cc_us)
        erpc_nocc = run_system("erpc_nocc", n, width=1, use_onesided=False,
                               extra_cpu=2 * FAB.recv_post_us)
        farm = run_system("lockfree_farm", n, width=8, use_onesided=True,
                          extra_cpu=0.0)
        lite = run_system("async_lite", n, width=1, use_onesided=False,
                          extra_cpu=FAB.lite_serial_us)
        res[n] = dict(storm=storm, erpc=erpc, erpc_nocc=erpc_nocc,
                      farm=farm, lite=lite)
    for n, r in res.items():
        print(f"# n={n}: storm/erpc={r['storm']/r['erpc']:.2f}x "
              f"(paper 3.3x), storm/farm={r['storm']/r['farm']:.2f}x "
              f"(paper 3.6x), storm/lite={r['storm']/r['lite']:.2f}x "
              f"(paper 17.1x), erpc_nocc/erpc={r['erpc_nocc']/r['erpc']:.2f}x "
              f"(paper 1.53x)")
        assert r["storm"] > r["erpc"] > r["lite"]
        assert r["storm"] > r["farm"] > r["lite"]
    return res


if __name__ == "__main__":
    main()
