"""§4.4/§4.5 ablation: occupancy vs one-sided success (resize-and/or-cache).

Sweeps table occupancy; as collisions grow, more lookups chase pointers and
fall back to RPC — modeled throughput decays exactly the way the paper's
principle predicts (keep occupancy below ~60-70%).  Also reports the
cost-model decisions for the three framework integration points at the
production shapes (MoE dispatch / decode attention / embedding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_line, modeled_throughput_per_node, populate, time_jit
from repro.core import cost_model, hybrid as hy
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

N_NODES = 8
LANES = 32
N_BUCKETS = 256


def occupancy_point(fill_frac: float):
    keys = int(N_BUCKETS * fill_frac)
    cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=N_BUCKETS,
                             bucket_width=1, n_overflow=max(keys, 8),
                             max_chain=16)
    layout = ht.build_layout(cfg)
    t = SimTransport(N_NODES)
    state = ht.init_cluster_state(cfg)
    state, (klo, khi) = populate(cfg, layout, t, state, keys)
    rng = np.random.RandomState(5)
    src = rng.randint(0, N_NODES, (N_NODES, LANES))
    idx = rng.randint(0, keys, (N_NODES, LANES))
    kl = jnp.asarray(np.asarray(klo)[src, idx])
    kh = jnp.asarray(np.asarray(khi)[src, idx])

    @jax.jit
    def round_fn(state):
        st, _, found, *_rest, m = hy.hybrid_lookup(
            t, state, kl, kh, cfg, layout, use_onesided=True)
        return st, found, m

    (state, found, m), dt = time_jit(round_fn, state)
    assert bool(found.all())
    ops = N_NODES * LANES
    rpc_frac = float(m.rpc_fallback) / float(m.total)
    mops = modeled_throughput_per_node(
        reads_per_op=1.0, rpcs_per_op=rpc_frac,
        wire_bytes_per_op=float(m.wire.total_bytes) / ops, lanes=LANES)
    csv_line(f"hybrid/occ{int(fill_frac*100)}", dt / ops * 1e6,
             f"modeled_Mops_node={mops:.2f};rpc_frac={rpc_frac:.2f}")
    return rpc_frac, mops


def framework_choices():
    """The trace-time hybrid decisions at the assigned production shapes."""
    rows = [
        ("moe/granite/train_4k", cost_model.moe_dispatch_choice(
            tokens_per_shard=4096 * 16, d_model=1024, d_ff=512, n_experts=32,
            top_k=8, shards=16)),
        ("moe/deepseek/train_4k", cost_model.moe_dispatch_choice(
            tokens_per_shard=4096 * 16, d_model=2048, d_ff=1408, n_experts=64,
            top_k=6, shards=16)),
        ("attn/qwen2.5/decode_32k", cost_model.decode_attention_choice(
            seq_len=32768, n_kv_heads=8, n_q_heads=40, head_dim=128,
            batch_per_shard=8, shards=16)),
        ("attn/qwen2.5/decode_2k", cost_model.decode_attention_choice(
            seq_len=2048, n_kv_heads=8, n_q_heads=40, head_dim=128,
            batch_per_shard=8, shards=16)),
        ("embed/gemma2/train_4k", cost_model.embedding_lookup_choice(
            tokens_per_shard=4096 * 16, d_model=4608, vocab=256000, shards=16)),
    ]
    for name, c in rows:
        csv_line(f"hybrid_choice/{name}", c.onesided_time * 1e6,
                 f"mode={c.mode};onesided_MB={c.onesided_bytes/1e6:.1f};"
                 f"rpc_MB={c.rpc_bytes/1e6:.1f}")
    return rows


def main():
    fr = []
    for f in (0.2, 0.4, 0.6, 0.8, 1.0):
        fr.append(occupancy_point(f))
    # monotone: higher occupancy -> more pointer chasing -> more RPC
    rpcs = [x[0] for x in fr]
    assert rpcs == sorted(rpcs), rpcs
    framework_choices()


if __name__ == "__main__":
    main()
