"""Figure 4: Key-value lookups — Storm vs Storm(oversub) vs Storm(perfect),
parameterized over the data structure (``--ds {hash,btree}``).

Hash table (the paper's Fig. 4):
  * Storm          — RPC-only lookups (every op is a write-based RPC)
  * Storm(oversub) — one-two-sided on an oversubscribed table (low collision
                     rate -> most lookups finish with ONE one-sided read)
  * Storm(perfect) — address-cached: a warmup round on the measured key set
                     fills the client cache, so every measured lookup is a
                     single one-sided read of the exact slot (no data-path RPC)

Ordered B-link index (``--ds btree``) — the same probe through the same
generic hybrid (Storm Table 3), different metadata regime:
  * Storm          — RPC-only (owner-side separator walk per lookup)
  * Storm(cached)  — cached separator directory walked locally + ONE
                     one-sided leaf read (the ordered analogue of
                     Storm(perfect); stale routes fall back to RPC)

Reported per configuration and node count: one-sided success fraction,
round-trips/op, wire bytes/op, modeled Mops/s/node (the paper's y-axis),
plus CPU-sim wall time per op.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from common import (csv_line, modeled_throughput_per_node, populate, time_jit)
from repro.core import hybrid as hy
from repro.core import rpc as R
from repro.core import wireproto as Wp
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.testing.workloads import distinct_uint32, value_for

LANES = 32
KEYS_PER_NODE = 192


def run_config(name, n_nodes, *, oversub: bool, use_onesided: bool,
               cache: bool, lanes=LANES):
    n_buckets = 1024 if oversub else 128    # oversub => low occupancy
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=n_buckets,
                             bucket_width=1, n_overflow=KEYS_PER_NODE,
                             max_chain=12,
                             cache_slots=4096 if cache else 0)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    state, (klo, khi) = populate(cfg, layout, t, state, KEYS_PER_NODE)
    caches = (jax.vmap(lambda _: ht.init_cache(cfg))(jnp.arange(n_nodes))
              if cache else None)

    # fixed evaluation batch: every node looks up `lanes` uniform keys
    rng = np.random.RandomState(7)
    src = rng.randint(0, n_nodes, (n_nodes, lanes))
    idx = rng.randint(0, KEYS_PER_NODE, (n_nodes, lanes))
    kl = jnp.asarray(np.asarray(klo)[src, idx])
    kh = jnp.asarray(np.asarray(khi)[src, idx])

    @jax.jit
    def round_fn(state, caches):
        st, cch, found, val, ver, node, sidx, _, m = hy.hybrid_lookup(
            t, state, kl, kh, cfg, layout, cache=caches,
            use_onesided=use_onesided)
        return st, cch, found, m

    # warmup fills the address cache (Storm(perfect))
    state, caches, found, m = round_fn(state, caches)
    assert bool(found.all()), "all keys must be found"
    (state, caches, found, m), dt = time_jit(round_fn, state, caches)

    ops = n_nodes * lanes
    one_frac = float(m.onesided_success) / float(m.total)
    rpc_frac = float(m.rpc_fallback) / float(m.total)
    reads_per_op = 1.0 if use_onesided else 0.0
    wire_b = float(m.wire.total_bytes) / ops
    mops = modeled_throughput_per_node(
        reads_per_op=reads_per_op, rpcs_per_op=rpc_frac,
        wire_bytes_per_op=wire_b, lanes=lanes)
    csv_line(f"fig4/{name}/n{n_nodes}", dt / ops * 1e6,
             f"modeled_Mops_node={mops:.2f};onesided_frac={one_frac:.2f};"
             f"rpc_frac={rpc_frac:.2f};bytes_op={wire_b:.0f}")
    return mops, one_frac


def run_config_btree(name, n_nodes, *, use_onesided: bool, lanes=LANES):
    """The SAME lookup workload through the ordered index: generic hybrid
    probe with ds=btree (cached separators walked locally, one one-sided
    leaf read) vs the RPC-only owner-side walk."""
    cfg = bt.BTreeConfig(n_nodes=n_nodes, n_leaves=2 * KEYS_PER_NODE,
                         leaf_width=4, max_scan_leaves=4)
    layout = bt.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(7)
    allk = distinct_uint32(rng, n_nodes * KEYS_PER_NODE)
    per = allk.reshape(n_nodes, KEYS_PER_NODE)
    h = bt.make_rpc_handler(cfg, layout)
    for i in range(0, KEYS_PER_NODE, 64):
        k = jnp.asarray(per[:, i:i + 64], jnp.uint32)
        state, rep, _, _ = R.rpc_call(
            t, state, bt.home_of(cfg, k),
            bt.make_record(Wp.OP_BT_INSERT, k, jnp.zeros_like(k),
                           value=value_for(k)), h)
        assert (np.asarray(rep[..., 0]) == Wp.ST_OK).all()
    meta = (bt.refresh_meta(t, state, cfg, layout)[0]
            if use_onesided else None)

    pick = rng.randint(0, len(allk), (n_nodes, lanes))
    kl = jnp.asarray(allk[pick], jnp.uint32)
    kh = jnp.zeros_like(kl)

    @jax.jit
    def round_fn(state, meta):
        st, m2, found, val, ver, node, sidx, _, m = hy.hybrid_lookup(
            t, state, kl, kh, cfg, layout, cache=meta,
            use_onesided=use_onesided, ds=bt)
        return st, m2, found, m

    state, meta, found, m = round_fn(state, meta)
    assert bool(found.all()), "all keys must be found"
    (state, meta, found, m), dt = time_jit(round_fn, state, meta)

    ops = n_nodes * lanes
    one_frac = float(m.onesided_success) / float(m.total)
    rpc_frac = float(m.rpc_fallback) / float(m.total)
    reads_per_op = 1.0 if use_onesided else 0.0
    wire_b = float(m.wire.total_bytes) / ops
    mops = modeled_throughput_per_node(
        reads_per_op=reads_per_op, rpcs_per_op=rpc_frac,
        wire_bytes_per_op=wire_b, lanes=lanes)
    csv_line(f"fig4/{name}/n{n_nodes}", dt / ops * 1e6,
             f"modeled_Mops_node={mops:.2f};onesided_frac={one_frac:.2f};"
             f"rpc_frac={rpc_frac:.2f};bytes_op={wire_b:.0f}")
    return mops, one_frac


def main(node_counts=(4, 8, 16), ds="hash"):
    out = {}
    if ds == "btree":
        for n in node_counts:
            a = run_config_btree("btree_rpc_only", n, use_onesided=False)
            b = run_config_btree("btree_cached", n, use_onesided=True)
            out[n] = (a, b)
        for n, (a, b) in out.items():
            assert b[0] >= a[0], f"cached should beat rpc-only at n={n}"
            assert b[1] >= 0.99, f"fresh separators must probe one-sided n={n}"
        return out
    for n in node_counts:
        a = run_config("storm_rpc_only", n, oversub=False,
                       use_onesided=False, cache=False)
        b = run_config("storm_oversub", n, oversub=True, use_onesided=True,
                       cache=False)
        c = run_config("storm_perfect", n, oversub=True, use_onesided=True,
                       cache=True)
        out[n] = (a, b, c)
    # paper's claims: oversub > rpc-only; perfect > oversub (2.2x at 32)
    for n, (a, b, c) in out.items():
        assert b[0] >= a[0], f"oversub should beat rpc-only at n={n}"
        assert c[0] >= b[0], f"perfect should beat oversub at n={n}"
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--ds", choices=("hash", "btree"), default="hash",
                    help="which remote data structure serves the lookups")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(node_counts=(4,) if args.smoke else (4, 8, 16), ds=args.ds)
