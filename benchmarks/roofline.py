"""Roofline analysis over the dry-run artifacts (deliverable g).

Terms per (arch x shape x mesh), all in SECONDS per step per chip, against
TPU v5e-class constants (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):

  compute    = FLOPs_exec / peak — executed FLOPs from the ANALYTIC model
               (6·N_active·tokens style + attention terms + remat recompute);
               the XLA CPU cost analysis counts while bodies ONCE (trip
               counts ignored), so the compiled counter is reported only as
               a diagnostic column (xla_flops).
  memory     = HBM bytes from a documented analytic traffic model
               (optimizer update + gathered-weight passes + activation
               save/restore + KV-cache streaming).
  collective = per-device wire bytes parsed from the optimized HLO with
               while-loop trip counts APPLIED (dryrun.parse_collectives),
               with ring/bidirectional factors per op.

MODEL/EXEC ratio = useful FLOPs / executed FLOPs (<1 under remat recompute &
masked-block waste).  roofline_frac = useful compute time / step bound —
the number §Perf hillclimbs.
"""
from __future__ import annotations

import json
import pathlib
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def wire_bytes(op: str, size: int, g: int) -> float:
    g = max(g, 2)
    if op == "all-reduce":
        return 2 * (g - 1) / g * size
    if op == "all-gather":
        return (g - 1) / g * size
    if op == "reduce-scatter":
        return (g - 1) * size
    if op == "all-to-all":
        return (g - 1) / g * size
    return float(size)  # collective-permute


def _attn_flops(cfg, B, S, mult):
    """Attention matmul FLOPs (4·B·S^2·H·hd per layer, x0.5 causal)."""
    if not cfg.has_attention:
        return 0.0
    Hhd = cfg.n_heads * cfg.head_dim
    if cfg.family == "hybrid":
        napps = cfg.n_layers // max(cfg.shared_attn_every, 1)
        return mult * 4 * B * S * S * Hhd * napps * 0.5
    if cfg.local_global_pattern == 2 and cfg.sliding_window:
        Lg = cfg.n_layers // 2
        return mult * 4 * B * S * Hhd * (
            Lg * S * 0.5 + Lg * min(cfg.sliding_window, S))
    a = mult * 4 * B * S * S * Hhd * cfg.n_layers * 0.5
    if cfg.is_encdec:
        a += mult * 4 * B * (cfg.encoder_seq ** 2 * Hhd * cfg.encoder_layers * 0.5
                             + S * cfg.encoder_seq * Hhd * cfg.n_layers)
    return a


def flops_model(cfg, shape, chips):
    """(useful_flops, executed_flops) per device.

    Executed adds: remat re-forward (train: fwd 2 + bwd 4 + refwd 2 = 8 vs
    useful 6) and the seq-CP causal waste (2x attention for archs whose
    heads don't shard — qwen2.5/qwen1.5; DESIGN §5)."""
    N = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    seq_cp_waste = 2.0 if (cfg.has_attention and cfg.n_heads % 16 != 0) else 1.0
    if shape.kind == "train":
        useful = 6.0 * N * B * S + 3 * _attn_flops(cfg, B, S, 1.0)
        executed = 8.0 * N * B * S + 4 * _attn_flops(cfg, B, S, seq_cp_waste)
    elif shape.kind == "prefill":
        useful = 2.0 * N * B * S + _attn_flops(cfg, B, S, 1.0)
        executed = 2.0 * N * B * S + _attn_flops(cfg, B, S, seq_cp_waste)
    else:  # decode
        Hhd = cfg.n_heads * cfg.head_dim if cfg.has_attention else 0
        napps = (cfg.n_layers // max(cfg.shared_attn_every, 1)
                 if cfg.family == "hybrid" else cfg.n_layers)
        attn = 4.0 * B * S * Hhd * napps
        useful = 2.0 * N * B + attn
        executed = useful
    return useful / chips, executed / chips


def hbm_model(cfg, shape, chips, multi):
    """Analytic per-device HBM traffic (bytes/step) — documented coarse
    model: optimizer state r/w, gathered-weight passes, activation
    save+reload, cache streaming."""
    N = cfg.n_params()
    Na = cfg.n_active_params()
    B, S = shape.global_batch, shape.seq_len
    tp = 16
    dp = chips // tp
    b_loc = max(B // dp, 1)
    d = cfg.d_model
    L = cfg.n_layers + cfg.encoder_layers
    if shape.kind == "train":
        opt = 36.0 * N / chips            # master/m/v r+w (24) + grad r/w (12)
        weights = 3 * 2.0 * N / tp        # fwd + re-fwd + bwd passes, bf16/tp
        acts = 4.0 * b_loc * S * d * 2 * L  # save + reload + recompute traffic
        return opt + weights + acts
    params_serve = 2.0 * N / tp
    if shape.kind == "prefill":
        acts = 2.0 * b_loc * S * d * 2 * L
        cache = _cache_bytes(cfg, b_loc, S)
        return params_serve + acts + cache
    # decode: read weights (active only for MoE) + stream the cache
    cache = _cache_bytes(cfg, b_loc, S)
    return 2.0 * Na / tp + cache + 2.0 * b_loc * d * 2 * L


def _cache_bytes(cfg, b_loc, S):
    tp = 16
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = (cfg.n_layers * b_loc * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
        if cfg.is_encdec:
            kv += (cfg.n_layers * b_loc * cfg.encoder_seq
                   * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
        return kv / tp
    ssm = (cfg.n_layers * b_loc * cfg.ssm_heads * cfg.ssm_state
           * cfg.ssm_head_dim * 4 * 2) / tp
    if cfg.family == "hybrid":
        napps = cfg.n_layers // max(cfg.shared_attn_every, 1)
        ssm += (napps * b_loc * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2) / tp
    return ssm


def analyze(path: pathlib.Path):
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from repro.configs import SHAPES
    from repro.configs.registry import get

    rows = []
    for f in sorted(path.glob("*.json")):
        if "__" not in f.stem:
            continue
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            if d.get("status") == "skipped":
                rows.append({"cell": f.stem, "status": "skipped",
                             "why": d.get("skipped", d.get("error", ""))})
            continue
        cfg = get(d["arch"])
        shape = SHAPES[d["shape"]]
        multi = d["mesh"] == "multi"
        chips = 512 if multi else 256
        useful, executed = flops_model(cfg, shape, chips)
        t_comp = executed / PEAK_FLOPS
        hbm = hbm_model(cfg, shape, chips, multi)
        t_mem = hbm / HBM_BW
        coll_wire = 0.0
        for op, info in d.get("collectives", {}).items():
            for gk, b in info.get("by_group", {}).items():
                coll_wire += wire_bytes(op, b, int(gk))
        t_coll = coll_wire / LINK_BW
        bound, dom = max((t_comp, "compute"), (t_mem, "memory"),
                         (t_coll, "collective"))
        rows.append({
            "cell": f.stem, "status": "ok", "arch": d["arch"],
            "shape": d["shape"], "mesh": d["mesh"], "kind": d.get("kind"),
            "t_compute_ms": t_comp * 1e3, "t_memory_ms": t_mem * 1e3,
            "t_collective_ms": t_coll * 1e3, "dominant": dom,
            "useful_flops": useful, "executed_flops": executed,
            "useful_ratio": useful / max(executed, 1),
            "roofline_frac": (useful / PEAK_FLOPS) / bound,
            "collective_bytes_wire": coll_wire,
            "hbm_bytes": hbm,
            "xla_flops": d["cost"].get("flops", 0.0),
            "xla_bytes": d["cost"].get("bytes accessed", 0.0),
        })
    return rows


def to_markdown(rows):
    out = ["| cell | kind | compute ms | memory ms | collective ms | "
           "dominant | useful/exec | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['cell']} | skipped | — | — | — | — | — | "
                       f"{r['why'][:60]} |")
            continue
        out.append(
            f"| {r['cell']} | {r['kind']} | {r['t_compute_ms']:.3f} | "
            f"{r['t_memory_ms']:.3f} | {r['t_collective_ms']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} |")
    return "\n".join(out)


def main():
    rows = analyze(RESULTS)
    print(to_markdown(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"\n# {len(ok)} cells analyzed")
    for crit, keyf in [
        ("worst roofline fraction", lambda r: r["roofline_frac"]),
        ("most collective-bound",
         lambda r: -r["t_collective_ms"] / max(r["t_compute_ms"], 1e-9)),
    ]:
        pick = sorted(ok, key=keyf)[:4]
        print(f"# {crit}: " + ", ".join(
            f"{p['cell']} ({keyf(p):.3f})" for p in pick))
    (RESULTS / "roofline.md").write_text(to_markdown(rows))
    (RESULTS / "roofline.json").write_text(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
