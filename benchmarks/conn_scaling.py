"""Connection-mode scaling: RC-exclusive vs RC-shared vs DCT beyond the rack.

The paper's guideline (§3.4, Fig. 7): keep exclusive sibling-thread RC inside
the rack — it is lock-free and its QP state still fits the NIC cache — and
switch to QP sharing or DCT beyond it, where 2·m·t connections of state thrash
the cache and every op pays a PCIe fetch of evicted QP state.  This benchmark
sweeps nodes × threads × connection mode over the SHARED model in
``repro.core.nic`` (the same ConnTable the protocol stack threads through its
wire accounting — no constants live here) and checks the guideline:

  * rc_exclusive is the fastest mode at rack scale (32 nodes);
  * rc_exclusive degrades ~1.57x by 96 nodes at 20 threads;
  * rc_shared and dct stay flat and sustain >= 1.3x the rc_exclusive
    throughput at 96 nodes / 20 threads.

A protocol-simulator section then runs the real fused OCC transaction loop
(SimTransport) with each mode's ConnTable threaded through the transport, so
the reported WireStats carry the modeled NIC-cache hit rate end-to-end —
every benchmark in this tree can now ask "what happens at 128 nodes?".

    PYTHONPATH=src python benchmarks/conn_scaling.py [--smoke]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import replication_cost
from common import (csv_line, make_tx_workload, modeled_throughput_per_node,
                    time_jit)
from repro.core import nic as qn
from repro.core import replication as repl
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

# per-op protocol profile of the sweep (one one-sided read, fig7's wire size)
READS_PER_OP = 1.0
WIRE_BYTES_PER_OP = 140
LANES = 32


def modeled(m_nodes: int, threads: int, mode: str = qn.RC_EXCLUSIVE):
    ct = qn.ConnTable(n_nodes=m_nodes, threads=threads, mode=mode)
    mops = modeled_throughput_per_node(
        reads_per_op=READS_PER_OP, rpcs_per_op=0.0,
        wire_bytes_per_op=WIRE_BYTES_PER_OP, lanes=LANES, nic=ct)
    return mops, ct


def sweep(node_counts, thread_counts):
    """CSV sweep + the paper's guideline assertions.  Returns {(mode, t, m):
    mops}."""
    out = {}
    for mode in qn.MODES:
        for t in thread_counts:
            for m in node_counts:
                mops, ct = modeled(m, t, mode)
                out[(mode, t, m)] = mops
                csv_line(
                    f"conn/{mode}/t{t}/m{m}", 1.0 / mops,
                    f"modeled_Mops_node={mops:.2f};"
                    f"qp_cache_hit={ct.cache_hit:.3f};"
                    f"conns_node={ct.conns_per_node};"
                    f"state_KiB={ct.state_bytes / 1024:.0f};"
                    f"penalty_us_op={ct.penalty_us_per_op:.4f}")
    return out


def check_guideline(mops, node_counts, thread_counts):
    m_rack, m_big = node_counts[0], node_counts[-1]
    t_hi = max(thread_counts)
    assert 96 in node_counts, "guideline is anchored at the paper's 96 nodes"
    # 1) inside the rack, exclusive RC wins (sharing locks / reconnects cost
    #    more than the cache pressure they relieve)
    for t in thread_counts:
        ex = mops[(qn.RC_EXCLUSIVE, t, m_rack)]
        assert ex >= mops[(qn.RC_SHARED, t, m_rack)], (t, m_rack)
        assert ex >= mops[(qn.DCT, t, m_rack)], (t, m_rack)
    # 2) beyond the rack at high thread count, sharing and DCT win big
    ex96 = mops[(qn.RC_EXCLUSIVE, t_hi, 96)]
    sh96 = mops[(qn.RC_SHARED, t_hi, 96)]
    dc96 = mops[(qn.DCT, t_hi, 96)]
    print(f"# 96 nodes / {t_hi} threads: rc_shared/rc_exclusive = "
          f"{sh96 / ex96:.2f}x, dct/rc_exclusive = {dc96 / ex96:.2f}x "
          f"(guideline: both >= 1.3x)")
    assert sh96 >= 1.3 * ex96, (sh96, ex96)
    assert dc96 >= 1.3 * ex96, (dc96, ex96)
    # 3) shared/DCT state stays cache-resident across the whole sweep: flat
    for mode in (qn.RC_SHARED, qn.DCT):
        flat = mops[(mode, t_hi, m_rack)] / mops[(mode, t_hi, m_big)]
        assert flat < 1.05, (mode, flat)


def sim_section(emulated_nodes: int, threads: int, modes=qn.MODES, *,
                sim_nodes: int = 4, lanes: int = 8, seed: int = 7,
                rep_fs=(0, 1)):
    """Run the REAL fused OCC loop with each mode's ConnTable threaded through
    the transport: protocol metrics come from the simulator, connection-state
    costs from the emulated scale (the paper's emulation methodology).

    The replication axis (`rep_fs`) shows the replication x connection-mode
    trade-off: backup fan-out adds DELIVERED REQUESTS (not exchange rounds),
    and every extra request pays the mode's per-op connection-state penalty —
    so the throughput edge of the state-frugal modes (rc_shared / dct) over
    cache-thrashed exclusive RC WIDENS as f grows."""
    cfg = ht.HashTableConfig(n_nodes=sim_nodes, n_buckets=256, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(sim_nodes)
    base_state = ht.init_cluster_state(cfg)
    base_state, rk, wk, wv = make_tx_workload(
        t, cfg, layout, base_state, lanes=lanes, n_keys=64, seed=seed)

    mtx = {}
    rounds = {}
    for mode in modes:
        ct = qn.ConnTable(n_nodes=emulated_nodes, threads=threads, mode=mode)
        for f in rep_fs:
            rep = repl.ReplicaConfig(sim_nodes, f)

            @jax.jit
            def round_fn(state, ct=ct, rep=rep):
                st, _, res = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                                         write_keys=wk, write_values=wv,
                                         max_rounds=2, nic=ct, rep=rep)
                return st, res

            (_, res), dt = time_jit(round_fn, base_state, iters=1)
            n_tx = sim_nodes * lanes
            w = res.metrics.wire
            ops_tx = float(w.ops) / n_tx
            # one pricing formula for replicated transactions, shared with
            # replication_cost and the bench gate (single source of truth)
            mops = replication_cost.modeled_mtx(
                dict(bytes_tx=float(w.total_bytes) / n_tx, ops_tx=ops_tx),
                f, ct)
            mtx[(mode, f)] = mops
            rounds[(mode, f)] = float(res.round_trips)
            csv_line(
                f"connsim/{mode}/m{emulated_nodes}t{threads}/f{f}",
                dt / n_tx * 1e6,
                f"modeled_Mtx_node={mops:.2f};"
                f"commit_rate={float(jnp.mean(res.committed)):.3f};"
                f"wire_hit_rate={float(w.nic_hit_rate):.3f};"
                f"wire_penalty_us_op={float(w.nic_penalty_us_per_op):.4f};"
                f"ops_tx={ops_tx:.2f};"
                f"bytes_tx={float(w.total_bytes) / n_tx:.0f}")
            # the wire accounting must carry exactly the mode's modeled hit
            # rate — backup classes included
            assert abs(float(w.nic_hit_rate) - ct.cache_hit) < 1e-4, (mode, f)

    # replication adds zero exchange rounds under EVERY connection mode
    for mode in modes:
        for f in rep_fs:
            assert rounds[(mode, f)] == rounds[(mode, rep_fs[0])], (mode, f)
    # ... and the replication x connection-mode trade-off: each backup write
    # is one more delivered request, so the ABSOLUTE per-tx connection-state
    # penalty gap between cache-thrashed exclusive RC and the state-frugal
    # modes widens with f, while the RELATIVE throughput edge only dilutes
    # mildly (the extra ops also pay mode-independent NIC-slot/wire costs) —
    # i.e. the "switch modes beyond the rack" guideline survives replication.
    f_lo, f_hi = rep_fs[0], rep_fs[-1]
    if f_hi > f_lo and qn.RC_EXCLUSIVE in modes:
        ct_ex = qn.ConnTable(n_nodes=emulated_nodes, threads=threads,
                             mode=qn.RC_EXCLUSIVE)
        for mode in modes:
            if mode == qn.RC_EXCLUSIVE:
                continue
            ct_m = qn.ConnTable(n_nodes=emulated_nodes, threads=threads,
                                mode=mode)
            d_pen = ct_ex.penalty_us_per_op - ct_m.penalty_us_per_op
            r_lo = mtx[(mode, f_lo)] / mtx[(qn.RC_EXCLUSIVE, f_lo)]
            r_hi = mtx[(mode, f_hi)] / mtx[(qn.RC_EXCLUSIVE, f_hi)]
            print(f"# {mode}/rc_exclusive at m={emulated_nodes}: "
                  f"{r_lo:.2f}x (f={f_lo}) -> {r_hi:.2f}x (f={f_hi}); "
                  f"penalty gap {d_pen:.4f}us/op scales with ops/tx")
            # the advantage survives the wider fan-out (within 10%)...
            assert r_hi >= r_lo * 0.90, (mode, r_lo, r_hi)
            # ...and in the thrashing regime it stays a real (>15%) win
            if ct_ex.cache_hit < 1.0:
                assert r_hi >= 1.15, (mode, r_hi)


def main(*, smoke: bool = False):
    node_counts = (32, 96) if smoke else (32, 64, 96, 128)
    thread_counts = (20,) if smoke else (10, 20)
    mops = sweep(node_counts, thread_counts)
    check_guideline(mops, node_counts, thread_counts)
    drop = (mops[(qn.RC_EXCLUSIVE, 20, 32)]
            / mops[(qn.RC_EXCLUSIVE, 20, 96)])
    print(f"# rc_exclusive 20-thread drop at 96 nodes: {drop:.2f}x "
          f"(paper 1.57x)")
    sim_section(96, 20, modes=(qn.RC_EXCLUSIVE, qn.DCT) if smoke else qn.MODES)


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
