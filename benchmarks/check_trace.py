"""Validate the flight recorder's exported artifacts (CI's trace check).

Hand-rolled structural validation — no external JSON-schema dependency —
of the two files ``benchmarks/run.py --trace`` writes:

  * the Chrome/Perfetto trace-event document: well-formed "M"/"X"/"C"
    events, per-process metadata for every node track, monotone modeled
    timestamps, positive slice durations, the ``otherData`` health block
    (and zero dropped events — a smoke run must fit its buffer);
  * the flat ``metrics.json``: string -> finite number, including the
    latency-percentile keys the bench gate pins.

    PYTHONPATH=src python benchmarks/check_trace.py trace.json [metrics.json]

Exit 0 when both validate; every violation is printed as ``TRACE-CHECK
FAIL: ...`` and exits 1.
"""
from __future__ import annotations

import json
import math
import sys

REQUIRED_METRICS = (
    "tatp.latency_us.committed.p50",
    "tatp.latency_us.committed.p99",
    "tatp.commit_rate",
    "tatp.trace_dropped",
)


def check_trace(doc) -> list[str]:
    fails = []

    def need(cond, msg):
        if not cond:
            fails.append(msg)
        return cond

    if not need(isinstance(doc, dict), "trace document is not an object"):
        return fails
    ev = doc.get("traceEvents")
    if not need(isinstance(ev, list) and ev, "traceEvents missing or empty"):
        return fails
    od = doc.get("otherData")
    if need(isinstance(od, dict), "otherData block missing"):
        for k in ("events", "dropped", "n_nodes", "modeled_span_us"):
            need(k in od, f"otherData.{k} missing")
        need(od.get("dropped") == 0,
             f"trace dropped {od.get('dropped')} events — the smoke run "
             f"must fit its buffer")
    pids = set()
    n_slices = 0
    last_ts = -1.0
    for i, e in enumerate(ev):
        if not need(isinstance(e, dict) and "ph" in e,
                    f"traceEvents[{i}] is not an event object"):
            continue
        ph = e["ph"]
        if not need(ph in ("M", "X", "C"),
                    f"traceEvents[{i}]: unknown event type {ph!r}"):
            continue
        if ph == "M":
            need(e.get("name") == "process_name"
                 and isinstance(e.get("args", {}).get("name"), str),
                 f"traceEvents[{i}]: metadata event without a process name")
            pids.add(e.get("pid"))
            continue
        need(isinstance(e.get("ts"), (int, float)) and e["ts"] >= 0,
             f"traceEvents[{i}]: bad ts {e.get('ts')!r}")
        need(e.get("pid") in pids,
             f"traceEvents[{i}]: pid {e.get('pid')!r} has no process "
             f"metadata track")
        if ph == "X":
            n_slices += 1
            need(isinstance(e.get("dur"), (int, float)) and e["dur"] > 0,
                 f"traceEvents[{i}]: slice without positive dur")
            need(isinstance(e.get("name"), str) and e["name"],
                 f"traceEvents[{i}]: unnamed slice")
            args = e.get("args", {})
            for k in ("round", "msgs", "bytes", "ops"):
                need(isinstance(args.get(k), (int, float)),
                     f"traceEvents[{i}]: slice args.{k} missing")
            if isinstance(e.get("ts"), (int, float)):
                need(e["ts"] >= last_ts,
                     f"traceEvents[{i}]: modeled timeline not monotone")
                last_ts = e["ts"]
        else:  # "C"
            need(isinstance(e.get("args"), dict) and e["args"],
                 f"traceEvents[{i}]: counter event without args")
    need(n_slices > 0, "no 'X' slices — the recorder captured no rounds")
    return fails


def check_metrics(doc) -> list[str]:
    fails = []
    if not isinstance(doc, dict) or not doc:
        return ["metrics document is not a non-empty object"]
    for k, v in doc.items():
        if not isinstance(k, str):
            fails.append(f"non-string metrics key {k!r}")
        if not isinstance(v, (int, float)) or (
                isinstance(v, float) and not math.isfinite(v)):
            fails.append(f"metrics[{k!r}] is not a finite number: {v!r}")
    for k in REQUIRED_METRICS:
        if k not in doc:
            fails.append(f"required metrics key missing: {k}")
    if doc.get("tatp.trace_dropped", 0) != 0:
        fails.append(f"tatp.trace_dropped = {doc['tatp.trace_dropped']} "
                     f"(must be 0 for the smoke run)")
    return fails


def main(argv) -> int:
    if not argv:
        print("usage: check_trace.py trace.json [metrics.json]")
        return 2
    fails = []
    with open(argv[0]) as f:
        fails += [f"{argv[0]}: {m}" for m in check_trace(json.load(f))]
    if len(argv) > 1:
        with open(argv[1]) as f:
            fails += [f"{argv[1]}: {m}" for m in check_metrics(json.load(f))]
    for m in fails:
        print(f"TRACE-CHECK FAIL: {m}")
    if not fails:
        print(f"# trace check green: {', '.join(argv)} validate")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
