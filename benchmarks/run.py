"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig4 fig6  # a subset
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI: small node counts
    PYTHONPATH=src python -m benchmarks.run --trace out.json   # + flight rec.

CSV lines: name,us_per_call,derived.  The roofline section reads the
dry-run artifacts under benchmarks/results/ (produced by
``python -m repro.launch.dryrun --all --mesh both``).

``--trace out.json`` additionally runs the deterministic TATP smoke with the
flight recorder enabled (core/telemetry.py) and writes two artifacts: the
Perfetto-loadable trace-event document at the given path, and a flat
``metrics.json`` next to it carrying the latency percentiles per
abort-retry path plus the membership/replication counters.  Validate both
with ``python benchmarks/check_trace.py out.json metrics.json``.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

ALL = ["fig4", "fig5", "fig6", "table5", "fig7", "conn", "range",
       "membership", "physseg", "hybrid", "roofline"]


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    trace_out = None
    if "--trace" in args:
        i = args.index("--trace")
        if i + 1 >= len(args):
            raise SystemExit("--trace needs an output path")
        trace_out = args[i + 1]
        args = args[:i] + args[i + 2:]
    want = [a for a in args if not a.startswith("--")] or ALL
    print("name,us_per_call,derived")
    if "fig4" in want:
        import fig4_lookups
        fig4_lookups.main(node_counts=(4,) if smoke else (4, 8, 16))
    if "fig5" in want:
        import fig5_comparison
        fig5_comparison.main(node_counts=(4,) if smoke else (4, 8, 16))
    if "fig6" in want:
        import fig6_tatp
        fig6_tatp.main(node_counts=(4,) if smoke else (4, 8))
    if "table5" in want:
        import table5_latency
        table5_latency.main()
    if "fig7" in want:
        import fig7_emulation
        fig7_emulation.main()
    if "conn" in want:
        import conn_scaling
        conn_scaling.main(smoke=smoke)
    if "range" in want:
        import range_scan
        range_scan.main(node_counts=(4,) if smoke else (4, 8), smoke=smoke)
    if "membership" in want:
        import membership_churn
        membership_churn.main(smoke=smoke)
    if smoke:
        for name in ("physseg", "hybrid", "roofline"):
            if name in want:
                print(f"{name}/SKIPPED,0,not part of the --smoke sweep")
    if "physseg" in want and not smoke:
        import physseg
        physseg.main()
    if "hybrid" in want and not smoke:
        import hybrid_ablation
        hybrid_ablation.main()
    if "roofline" in want and not smoke:
        results = pathlib.Path(__file__).resolve().parent / "results"
        if any(results.glob("*__*.json")):
            import roofline
            rows = roofline.analyze(results)
            ok = [r for r in rows if r["status"] == "ok"]
            for r in ok:
                bound = max(r["t_compute_ms"], r["t_memory_ms"],
                            r["t_collective_ms"])
                print(f"roofline/{r['cell']},{bound*1e3:.1f},"
                      f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
                      f"comp_ms={r['t_compute_ms']:.3f};"
                      f"mem_ms={r['t_memory_ms']:.3f};"
                      f"coll_ms={r['t_collective_ms']:.3f}")
            (results / "roofline.md").write_text(roofline.to_markdown(rows))
        else:
            print("roofline/SKIPPED,0,run repro.launch.dryrun first")
    if trace_out is not None:
        import fig6_tatp
        import membership_churn
        import replication_cost
        from repro.core.telemetry import MetricsRegistry

        reg = MetricsRegistry()
        membership_churn.fill_registry(reg)
        replication_cost.fill_registry(reg)
        out = pathlib.Path(trace_out)
        out.parent.mkdir(parents=True, exist_ok=True)
        metrics = out.parent / "metrics.json"
        fig6_tatp.traced_smoke(str(out), str(metrics), registry=reg)
        print(f"# wrote {out} and {metrics} (validate with check_trace.py)")


if __name__ == "__main__":
    main()
