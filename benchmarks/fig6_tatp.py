"""Figure 6: TATP on Storm — Storm(oversub) one-two-sided vs RPC-only Storm.

TATP mix (the standard benchmark mix, grouped to the paper's 80/16/4 split):
    80% read transactions   (GET_SUBSCRIBER_DATA / GET_NEW_DESTINATION /
                             GET_ACCESS_DATA -> 1-2 reads)
    16% update transactions (UPDATE_SUBSCRIBER_DATA / UPDATE_LOCATION
                             -> 1 read + 1 write)
     4% insert/delete       (INSERT/DELETE_CALL_FORWARDING -> 1 write)

Each lane runs one transaction through the FULL OCC protocol (execute /
lock / validate / commit — Fig. 3) on the FUSED round schedule (read,
fallback∥lock∥validate, commit: ≤ 4 exchange rounds per protocol round, 3 on
the all-one-sided fast path — `fused=False` reproduces the 5-round per-phase
reference).  The oversubscribed configuration serves reads one-sided; the
baseline forces every read through RPC.  Reported: committed tx/s (modeled),
abort rate, wire bytes/tx, exchange rounds per protocol round (`rt_round`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import (ModelFabric, csv_line, modeled_throughput_per_node,
                    populate, time_jit)
from repro.core import slots as sl
from repro.core import telemetry as T
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

LANES = 16
SUBSCRIBERS_PER_NODE = 160
FAB = ModelFabric()
RD, WR = 2, 1   # static read/write set sizes (masked per mix)
MAX_ROUNDS = 4  # bounded retry (tx_loop); 1 reproduces single-shot


def run_config(name, n_nodes, *, use_onesided: bool, oversub: bool,
               lanes=LANES, seed=3, max_rounds=MAX_ROUNDS, fused=True,
               telemetry=None):
    n_buckets = 1024 if oversub else 128
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=n_buckets,
                             bucket_width=1, n_overflow=SUBSCRIBERS_PER_NODE,
                             max_chain=12)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    state, (klo, khi) = populate(cfg, layout, t, state, SUBSCRIBERS_PER_NODE,
                                 seed=seed)
    rng = np.random.RandomState(seed + 1)

    def draw_tx():
        """Returns read_keys (N,L,RD,2), write_keys (N,L,WR,2), masks."""
        def pick(n):
            s = rng.randint(0, n_nodes, (n_nodes, lanes, n))
            i = rng.randint(0, SUBSCRIBERS_PER_NODE, (n_nodes, lanes, n))
            return (np.asarray(klo)[s, i], np.asarray(khi)[s, i])
        rl, rh = pick(RD)
        wl, wh = pick(WR)
        kind = rng.rand(n_nodes, lanes)
        is_read = kind < 0.80                 # read-only tx
        two_reads = kind < 0.40               # GET_NEW_DESTINATION-like
        read_en = np.ones((n_nodes, lanes, RD), bool)
        read_en[..., 1] = two_reads
        read_en[~is_read, 1] = False          # updates read 1 row
        write_en = np.repeat((~is_read)[..., None], WR, axis=-1)
        rk = jnp.asarray(np.stack([rl, rh], -1), jnp.uint32)
        wk = jnp.asarray(np.stack([wl, wh], -1), jnp.uint32)
        return rk, wk, jnp.asarray(read_en), jnp.asarray(write_en)

    rk, wk, ren, wen = draw_tx()
    wvals = sl._mix32(wk[..., 0] + jnp.uint32(99))[..., None] * \
        jnp.ones((sl.VALUE_WORDS,), jnp.uint32)

    @jax.jit
    def round_fn(state):
        out = txl.tx_loop(
            t, state, cfg, layout, read_keys=rk, write_keys=wk,
            write_values=wvals, read_enabled=ren, write_enabled=wen,
            use_onesided=use_onesided, max_rounds=max_rounds, fused=fused,
            telemetry=telemetry)
        if telemetry is not None:
            st, _, res, tel = out
            return st, res, tel
        st, _, res = out
        return st, res, None

    (state, res, tel), dt = time_jit(round_fn, state)
    n_tx = n_nodes * lanes
    committed = float(jnp.sum(res.committed)) / n_tx
    retries = int(jnp.sum(res.round_retries))
    # exchange round trips per attempted protocol round: the fused schedule
    # must stay within 4 (3 on the all-one-sided fast path) vs 5 per-phase
    rounds_attempted = int((np.asarray(res.round_attempts) > 0).sum())
    rt_round = float(res.round_trips) / max(rounds_attempted, 1)
    if fused:
        assert float(res.round_trips) <= 4.0 * rounds_attempted, (
            f"fused schedule exceeded 4 exchanges/round: "
            f"{float(res.round_trips)} over {rounds_attempted} rounds")
    ab_lock = int(jnp.sum(res.round_abort_lock))
    ab_val = int(jnp.sum(res.round_abort_validate))
    ab_ovf = int(jnp.sum(res.round_abort_overflow))
    m = res.metrics
    rpc_frac = float(m.rpc_fallback) / max(float(m.total), 1)
    wire_tx = float(m.wire.total_bytes) / n_tx
    msg_tx = float(m.wire.messages) / n_tx
    # per-tx primitive counts: reads (hybrid) + lock RPC + validate read +
    # commit RPC (write lanes), scaled by the average protocol executions per
    # tx (retry rounds re-issue the live lanes' ops) so the slot/RT terms
    # stay consistent with wire_tx, which also totals every retry round:
    exec_per_tx = float(jnp.sum(res.round_attempts)) / n_tx
    reads_per_tx = (float(jnp.sum(ren)) / n_tx) * (1.0 if use_onesided else 0.0)
    rpcs_per_tx = (float(jnp.sum(ren)) / n_tx) * (rpc_frac if use_onesided else 1.0)
    rpcs_per_tx += 2.0 * float(jnp.sum(wen)) / n_tx      # lock + commit
    reads_per_tx += float(jnp.sum(ren)) / n_tx           # validation re-read
    reads_per_tx *= exec_per_tx
    rpcs_per_tx *= exec_per_tx
    mtps = modeled_throughput_per_node(
        reads_per_op=reads_per_tx, rpcs_per_op=rpcs_per_tx,
        wire_bytes_per_op=wire_tx, lanes=lanes)
    csv_line(f"fig6/{name}/n{n_nodes}", dt / n_tx * 1e6,
             f"modeled_Mtx_node={mtps:.2f};commit_rate={committed:.3f};"
             f"read_rpc_frac={rpc_frac:.2f};bytes_tx={wire_tx:.0f};"
             f"msgs_tx={msg_tx:.1f};rt_round={rt_round:.2f};"
             f"retries={retries};"
             f"aborts_lock/val/ovf={ab_lock}/{ab_val}/{ab_ovf}")
    if telemetry is not None:
        return mtps, committed, rt_round, res, tel
    return mtps, committed, rt_round


def traced_smoke(trace_path=None, metrics_path=None, *, n_nodes=4,
                 registry=None):
    """The TATP smoke with the flight recorder ON: one deterministic
    oversubscribed run, exported as a Perfetto-loadable trace plus a flat
    metrics.json (latency percentiles per abort-retry path, abort counters,
    trace health).  The bench gate pins the p50/p99 committed-latency keys.

    Returns (MetricsRegistry, trace-event document dict)."""
    tcfg = T.TelemetryConfig()
    mtps, committed, rt_round, res, tel = run_config(
        "storm_oversub_traced", n_nodes, use_onesided=True, oversub=True,
        telemetry=tcfg)
    reg = registry if registry is not None else T.MetricsRegistry()
    reg.set("tatp.commit_rate", committed)
    reg.set("tatp.modeled_mtx_node", mtps)
    reg.set("tatp.rt_round", rt_round)
    reg.set("tatp.round_trips", float(res.round_trips))
    reg.set("tatp.retries", float(jnp.sum(res.round_retries)))
    reg.set("tatp.abort_lock", float(jnp.sum(res.round_abort_lock)))
    reg.set("tatp.abort_validate", float(jnp.sum(res.round_abort_validate)))
    reg.set("tatp.abort_overflow", float(jnp.sum(res.round_abort_overflow)))
    reg.set("tatp.trace_events", int(tel.trace.n))
    reg.set("tatp.trace_dropped", int(tel.trace.dropped))
    lat = np.asarray(tel.lane_latency_us)
    com = np.asarray(res.committed)
    reg.observe("tatp.latency_us", lat[com])
    for group, summ in T.latency_by_path(tel.lane_latency_us, res.committed,
                                         res.commit_round).items():
        for k, v in summ.items():
            reg.set(f"tatp.latency_us.{group}.{k}", v)
    doc = T.export_trace(tel.trace, config=tcfg, path=trace_path,
                         label="tatp")
    if metrics_path is not None:
        reg.write(metrics_path)
    return reg, doc


def main(node_counts=(4, 8, 16)):
    for n in node_counts:
        a, ca, _ = run_config("storm_rpc_reads", n, use_onesided=False,
                              oversub=False)
        b, cb, rtf = run_config("storm_oversub", n, use_onesided=True,
                                oversub=True)
        print(f"# n={n}: oversub/rpc = {b/a:.2f}x (paper 1.49x at 32 nodes); "
              f"commit rates {ca:.2f}/{cb:.2f}")
        assert b > a
    # the fused schedule's whole point: fewer exchanges than the 5-round
    # per-phase reference on the same workload
    n0 = node_counts[0]
    _, _, rt5 = run_config("storm_oversub_5round", n0, use_onesided=True,
                           oversub=True, fused=False)
    _, _, rt4 = run_config("storm_oversub_fused", n0, use_onesided=True,
                           oversub=True, fused=True)
    print(f"# n={n0}: exchange rounds per protocol round "
          f"{rt5:.2f} (per-phase) -> {rt4:.2f} (fused)")
    assert rt4 < rt5, (rt4, rt5)
    assert rt4 <= 4.0
    return None


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:       # CI: one small node count
        main(node_counts=(4,))
    else:
        main()
