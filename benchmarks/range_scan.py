"""Range-scan benchmark: the ordered B-link index under scan/insert mixes.

The first multikey/ordered workload of the reproduction (cf. "RDMA vs. RPC
for Implementing Distributed Data Structures": ordered traversals favor
caching + one-sided reads, structural modifications favor RPC).  Sections:

  * **mix sweep** — scan-heavy (90% scan lanes) vs balanced vs insert-heavy
    (10%) through the bounded-retry ``txloop.scan_loop``: commit rate,
    aborts by cause, exchange rounds per protocol round, one-sided fraction
    of leaf reads, modeled Mtx/s/node;
  * **skew sweep** — scan start keys concentrated on a hot subrange vs
    uniform (contention on a few leaves vs spread);
  * **built-in assertions** —
      - the one-sided fast-path scan adds ZERO exchange rounds over the
        point-lookup schedule (scan tx rounds == read-only point tx rounds),
      - fused ≡ unfused committed results with fewer-or-equal rounds,
      - replication f=1 adds zero exchange rounds to the scan schedule.

``gate_numbers()`` feeds the CI bench gate (``bench_gate.py``): scan round
trips of a fixed deterministic workload + modeled Mscans/node at 32 emulated
nodes.

    PYTHONPATH=src python benchmarks/range_scan.py [--smoke]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from common import csv_line, modeled_throughput_per_node, time_jit
from repro.core import nic as qn
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import tx as txm
from repro.core import txloop as txl
from repro.core import wireproto as Wp
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.replication import ReplicaConfig
from repro.core.transport import SimTransport
from repro.testing.workloads import distinct_uint32, value_for

LANES = 8
KEYS_PER_NODE = 48
SPAN = 4            # scans cover this many consecutive keys


def build_tree(n_nodes, *, n_keys=KEYS_PER_NODE, seed=3):
    """Populated cluster + fresh separator cache + the sorted key array."""
    cfg = bt.BTreeConfig(n_nodes=n_nodes, n_leaves=2 * n_keys, leaf_width=4,
                         max_scan_leaves=8)
    layout = bt.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(seed)
    allk = np.sort(distinct_uint32(rng, n_nodes * n_keys).astype(np.uint64))
    h = bt.make_rpc_handler(cfg, layout)
    flat = allk.astype(np.uint32)
    rng.shuffle(flat)
    per = flat.reshape(n_nodes, n_keys)
    for i in range(0, n_keys, 16):
        k = jnp.asarray(per[:, i:i + 16], jnp.uint32)
        state, rep, _, _ = R.rpc_call(
            t, state, bt.home_of(cfg, k),
            bt.make_record(Wp.OP_BT_INSERT, k, jnp.zeros_like(k),
                           value=value_for(k)), h)
        assert (np.asarray(rep[..., 0]) == Wp.ST_OK).all()
    meta, _ = bt.refresh_meta(t, state, cfg, layout)
    return cfg, layout, t, state, allk, meta


def scan_workload(allk, n_nodes, lanes, *, scan_frac, seed, theta=0.0):
    """Per-lane mix: `scan_frac` of lanes scan SPAN consecutive keys (start
    Zipf(theta)-skewed over the key array; 0 = uniform), the rest upsert a
    fresh gap key (a key strictly between two existing ones)."""
    rng = np.random.RandomState(seed)
    M = len(allk) - SPAN - 1
    if theta > 0:
        rank = np.arange(1, M + 1, dtype=np.float64)
        p = 1.0 / rank ** theta
        p /= p.sum()
        starts = rng.choice(M, (n_nodes, lanes), p=p)
    else:
        starts = rng.randint(0, M, (n_nodes, lanes))
    lo = allk[starts]
    hi = allk[starts + SPAN - 1]
    is_scan = rng.rand(n_nodes, lanes) < scan_frac
    # gap keys: midpoint between a key and its successor (fresh by
    # construction whenever the gap is > 1)
    g = rng.randint(0, len(allk) - 1, (n_nodes, lanes))
    wk = (allk[g] + np.maximum((allk[g + 1] - allk[g]) // 2, 1)).astype(
        np.uint64)
    return (jnp.asarray(np.where(is_scan, lo, 1), jnp.uint32),        # lo
            jnp.asarray(np.where(is_scan, hi, 0), jnp.uint32),        # hi>lo
            jnp.asarray(wk, jnp.uint32)[..., None],                   # (N,B,1)
            jnp.asarray(~is_scan, bool)[..., None])                   # write_en


def modeled_scan_mops(res, n_tx, lanes, *, n_emulated=32,
                      mode="rc_exclusive"):
    """Price the measured protocol counts with the paper's fabric constants
    + the connection-state model at `n_emulated` nodes: every leaf read pays
    a one-sided read twice (data + validate re-read), fallbacks pay an RPC."""
    n_com = max(float(jnp.sum(res.committed)), 1.0)
    wire = res.metrics.wire
    reads_per = 2.0 * float(res.metrics.total) / n_com
    rpcs_per = float(res.metrics.rpc_fallback) / n_com
    nic = qn.ConnTable(n_nodes=n_emulated, threads=20, mode=mode)
    return modeled_throughput_per_node(
        reads_per_op=reads_per, rpcs_per_op=rpcs_per,
        wire_bytes_per_op=float(wire.total_bytes) / n_com, lanes=lanes,
        nic=nic)


_loop_cache: dict = {}


def _loop_fn(t, cfg, layout, max_rounds):
    """One jitted scan_loop per (config, bound): the workload arrays are jit
    ARGUMENTS, so every mix/skew point reuses the same compilation."""
    key = (cfg, max_rounds)
    if key not in _loop_cache:
        _loop_cache[key] = jax.jit(
            lambda state, lo, hi, wk, wv, wen, meta: txl.scan_loop(
                t, state, cfg, layout, scan_lo=lo, scan_hi=hi, meta=meta,
                write_keys=wk, write_values=wv, write_enabled=wen,
                max_rounds=max_rounds))
    return _loop_cache[key]


def run_mix(n_nodes, scan_frac, *, theta=0.0, max_rounds=4, lanes=LANES,
            seed=7):
    cfg, layout, t, state, allk, meta = build_tree(n_nodes)
    lo, hi, wk, wen = scan_workload(allk, n_nodes, lanes,
                                    scan_frac=scan_frac, seed=seed,
                                    theta=theta)
    wv = value_for(wk)
    round_fn = _loop_fn(t, cfg, layout, max_rounds)
    (state, _, res), dt = time_jit(round_fn, state, lo, hi, wk, wv, wen, meta)
    n_tx = n_nodes * lanes
    committed = int(jnp.sum(res.committed))
    assert not bool(np.asarray(res.truncated).any()), \
        "SPAN-key scans must fit max_scan_leaves"
    rounds_attempted = int((np.asarray(res.round_attempts) > 0).sum())
    rt_round = float(res.round_trips) / max(rounds_attempted, 1)
    one_frac = (float(res.metrics.onesided_success)
                / max(float(res.metrics.total), 1.0))
    mops = modeled_scan_mops(res, n_tx, lanes)
    csv_line(
        f"range/n{n_nodes}/scan{int(scan_frac * 100)}"
        + (f"/theta{theta}" if theta else ""),
        dt / n_tx * 1e6,
        f"commit_rate={committed / n_tx:.3f};rt_round={rt_round:.2f};"
        f"onesided_frac={one_frac:.2f};"
        f"aborts_lock/val/ovf={int(jnp.sum(res.round_abort_lock))}/"
        f"{int(jnp.sum(res.round_abort_validate))}/"
        f"{int(jnp.sum(res.round_abort_overflow))};"
        f"modeled_Mtx_node={mops:.2f}")
    return committed, rt_round, res


def point_readonly_rounds(n_nodes=4, lanes=LANES):
    """Exchange rounds of a READ-ONLY point-lookup transaction on the fused
    fast path (the baseline the scan schedule must not exceed)."""
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=1024, bucket_width=1,
                             n_overflow=64, max_chain=8)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(11)
    klo = jnp.asarray(rng.randint(0, 2**31, (n_nodes, lanes)), jnp.uint32)
    khi = jnp.zeros_like(klo)
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(Wp.OP_INSERT, klo, khi,
                                       value=value_for(klo)), h)
    assert (np.asarray(rep[..., 0]) == Wp.ST_OK).all()
    rk = jnp.stack([klo, khi], -1)[:, :, None, :]
    _, _, res = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk,
        write_keys=jnp.zeros((n_nodes, lanes, 0, 2), jnp.uint32),
        write_values=jnp.zeros((n_nodes, lanes, 0, sl.VALUE_WORDS),
                               jnp.uint32))
    assert float(res.metrics.rpc_fallback) == 0.0, \
        "baseline must be the one-sided fast path"
    return float(res.round_trips)


def check_schedule_claims(n_nodes=4, lanes=LANES):
    """The headline assertions (also enforced by the bench gate)."""
    cfg, layout, t, state, allk, meta = build_tree(n_nodes, seed=5)
    lo, hi, _, _ = scan_workload(allk, n_nodes, lanes, scan_frac=1.0, seed=9)

    _, res_f = txm.run_scan_transactions(t, state, cfg, layout, scan_lo=lo,
                                         scan_hi=hi, meta=meta, fused=True)
    _, res_u = txm.run_scan_transactions(t, state, cfg, layout, scan_lo=lo,
                                         scan_hi=hi, meta=meta, fused=False)
    assert bool(np.asarray(res_f.committed).all())
    assert float(res_f.metrics.rpc_fallback) == 0.0, "fresh meta => fast path"
    np.testing.assert_array_equal(np.asarray(res_f.scan_keys),
                                  np.asarray(res_u.scan_keys))
    np.testing.assert_array_equal(np.asarray(res_f.scan_mask),
                                  np.asarray(res_u.scan_mask))
    assert float(res_f.round_trips) <= float(res_u.round_trips)

    pt = point_readonly_rounds(n_nodes, lanes)
    assert float(res_f.round_trips) == pt, \
        f"one-sided fast-path scan must add ZERO exchange rounds over the " \
        f"point-lookup schedule ({res_f.round_trips} vs {pt})"
    print(f"# range_scan: fast-path scan rounds == point-lookup rounds "
          f"({pt:.0f}); fused {res_f.round_trips:.0f} <= "
          f"unfused {res_u.round_trips:.0f}")

    # replication: backup classes ride the commit round — zero extra rounds
    lo2, hi2, wk, wen = scan_workload(allk, n_nodes, lanes, scan_frac=0.5,
                                      seed=13)
    wv = value_for(wk)
    _, r0 = txm.run_scan_transactions(
        t, state, cfg, layout, scan_lo=lo2, scan_hi=hi2, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen)
    _, r1 = txm.run_scan_transactions(
        t, state, cfg, layout, scan_lo=lo2, scan_hi=hi2, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen,
        rep=ReplicaConfig(n_nodes, 1))
    assert float(r1.round_trips) == float(r0.round_trips), \
        "f=1 must add zero exchange rounds to the scan schedule"
    print(f"# range_scan: f=1 adds zero exchange rounds "
          f"({r1.round_trips:.0f} == {r0.round_trips:.0f})")
    return float(res_f.round_trips)


def gate_numbers():
    """Deterministic ordered-index numbers for bench_gate.py: the fast-path
    scan's exchange rounds and the scan-heavy mix's modeled Mtx/node at 32
    emulated nodes."""
    rt = check_schedule_claims()
    cfg, layout, t, state, allk, meta = build_tree(4, seed=5)
    lo, hi, wk, wen = scan_workload(allk, 4, LANES, scan_frac=0.9, seed=7)
    _, _, res = txl.scan_loop(t, state, cfg, layout, scan_lo=lo, scan_hi=hi,
                              meta=meta, write_keys=wk,
                              write_values=value_for(wk), write_enabled=wen,
                              max_rounds=2)
    return {
        "scan_round_trips": rt,
        "commit_rate": round(float(jnp.mean(res.committed)), 4),
        "mops_node_32": round(modeled_scan_mops(res, 4 * LANES, LANES), 4),
    }


def main(node_counts=(4, 8), smoke=False):
    check_schedule_claims()
    for n in node_counts:
        base = None
        for frac in ((0.9, 0.1) if smoke else (0.9, 0.5, 0.1)):
            c, rt, _ = run_mix(n, frac)
            assert rt <= 4.0, f"fused scan schedule exceeded 4 rounds: {rt}"
            base = c if base is None else base
    # skew: hot-range scans contend on few leaves; the retry loop still
    # converges every lane
    for theta in ((1.2,) if smoke else (0.6, 1.2)):
        c, _, res = run_mix(node_counts[0], 0.5, theta=theta)
        assert bool(np.asarray(res.committed | res.truncated).all()), \
            "skewed mix must converge within the retry bound"


if __name__ == "__main__":
    import sys
    main(node_counts=(4,) if "--smoke" in sys.argv else (4, 8),
         smoke="--smoke" in sys.argv)
