"""Shared harness for the paper-figure benchmarks.

All figure benchmarks run on the N-node cluster SIMULATOR (SimTransport on
this container's single CPU device) and report two kinds of numbers:

  * protocol metrics (hardware-independent): round trips / op, wire bytes /
    op, one-sided success fraction — these are what Storm's design actually
    changes, and they reproduce the paper's RELATIVE claims;
  * modeled IOPS: protocol bytes/hops priced with the paper's own hardware
    constants (CX4-IB-class: ~1.8us one-sided RT, ~2.7us RPC RT, 100Gbps
    links, per-message CPU costs for send/recv systems) — the absolute
    scale of Figs 4-6;
  * CPU wall time is printed for transparency but is NOT the comparison
    metric (one CPU core simulates the whole cluster).
"""
from __future__ import annotations

import dataclasses
import sys
import time
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rpc as R
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht

# THE percentile helper: every benchmark reports latency distributions
# through this one summary ({p50, p90, p99, mean}) — never bare means, and
# never a private reimplementation.  It lives next to the flight recorder
# (core/telemetry.py) because the traced latency samples are produced there.
from repro.core.telemetry import summarize  # noqa: F401  (re-export)


# --- modeled fabric (CX4 Infiniband EDR) -------------------------------------
# Calibration (documented in EXPERIMENTS.md §Fig4/5): a one-sided read
# consumes a NIC slot at the requester AND the owner (2 slots of the ~40M/s
# read engine) plus fixed issue overhead -> T_READ ~= 0.085us/op/node, which
# puts Storm(perfect) at ~12 Mops/node — the paper's top line.  A write-based
# RPC adds the owner-side handler + completion (T_RPC ~= 0.18us) — the
# paper's RPC-only Storm at ~5.5 Mops/node.  Everything else (eRPC recv
# posting + app-level CC, FaRM 8x reads, LITE syscalls) layers on top of
# these two primitives with per-system terms from §6.2 / Table 5.
@dataclasses.dataclass(frozen=True)
class ModelFabric:
    t_read_us: float = 0.085             # per one-sided read (2 NIC slots)
    t_rpc_us: float = 0.18               # per write-based RPC (handler+CQ)
    link_gbps: float = 100.0
    rt_onesided_us: float = 1.8          # unloaded RT (Table 5)
    rt_rpc_us: float = 2.7
    recv_post_us: float = 0.04           # eRPC per-message RQ posting (x2/op)
    app_cc_us: float = 0.15              # eRPC app-level congestion control
    syscall_us: float = 1.55             # LITE kernel entry/exit + copy (latency)
    lite_serial_us: float = 1.8          # LITE throughput-path syscall+locks
    dma_seg_us_per_kb: float = 0.20      # large-read DMA segmentation (FaRM)


def modeled_throughput_per_node(*, reads_per_op: float, rpcs_per_op: float,
                                wire_bytes_per_op: float, lanes: int,
                                fabric: ModelFabric = ModelFabric(),
                                extra_cpu_us_per_op: float = 0.0,
                                nic=None):
    """Million ops/s/node for a pipelined (lanes deep) workload: the per-op
    serialization cost (NIC slots + wire bytes + CPU terms), floored by the
    latency/lanes term.

    nic: optional repro.core.nic.ConnTable — adds the modeled per-op
    connection-state penalty (NIC-cache misses of QP state, QP-sharing locks,
    DC reconnects) of that connection mode / emulated cluster scale."""
    wire_us = wire_bytes_per_op * 8 / (fabric.link_gbps * 1e3)
    slot_us = reads_per_op * fabric.t_read_us + rpcs_per_op * fabric.t_rpc_us
    rt_us = (reads_per_op * fabric.rt_onesided_us
             + rpcs_per_op * fabric.rt_rpc_us)
    if nic is not None:
        extra_cpu_us_per_op += nic.penalty_us_per_op
    per_op_us = max(slot_us + wire_us + extra_cpu_us_per_op,
                    rt_us / max(lanes, 1))
    return 1.0 / per_op_us  # Mops/s


def populate(cfg, layout, t, state, n_keys_per_node, seed=0):
    """Insert n keys per node; returns (state, key arrays (N, n))."""
    rng = np.random.RandomState(seed)
    N = cfg.n_nodes
    klo = jnp.asarray(rng.randint(0, 2**31, (N, n_keys_per_node)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, n_keys_per_node)), jnp.uint32)
    h = ht.make_rpc_handler(cfg, layout)
    B = 64
    for i in range(0, n_keys_per_node, B):
        kl, kh = klo[:, i:i + B], khi[:, i:i + B]
        node, _, _ = ht.lookup_start(cfg, layout, kl, kh)
        vals = sl._mix32(kl[..., None] + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32))
        state, rep, _, _ = R.rpc_call(
            t, state, node, ht.make_record(R.OP_INSERT, kl, kh, value=vals), h)
    return state, (klo, khi)


def make_tx_workload(t, cfg, layout, state, *, lanes, n_keys, seed):
    """Populate the table and draw a deterministic one-read/one-write
    transaction batch per lane (shared by bench_gate and conn_scaling so the
    gated workload and the benchmarked one can never diverge).

    Returns (state, read_keys (N, lanes, 1, 2), write_keys, write_values)."""
    state, (klo, khi) = populate(cfg, layout, t, state, n_keys, seed=seed)
    rng = np.random.RandomState(seed + 1)
    s = rng.randint(0, cfg.n_nodes, (cfg.n_nodes, lanes, 1))
    i = rng.randint(0, n_keys, (cfg.n_nodes, lanes, 1))
    rk = jnp.asarray(np.stack([np.asarray(klo)[s, i],
                               np.asarray(khi)[s, i]], -1), jnp.uint32)
    wk = rk ^ jnp.uint32(0x9E3779B9)    # disjoint write set
    wv = sl._mix32(wk[..., 0] + jnp.uint32(seed + 11))[..., None] * \
        jnp.ones((sl.VALUE_WORDS,), jnp.uint32)
    return state, rk, wk, wv


def time_jit(fn, *args, iters=3):
    """Compile + time a jitted callable; returns (result, best_seconds)."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")
