"""Quickstart: the Storm dataplane in ~40 lines.

Builds a 4-node distributed hash table (simulated cluster), inserts keys via
write-based RPCs, reads them back with one-two-sided hybrid lookups, and
runs one OCC transaction.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid, rpc, slots as sl, tx
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

N_NODES, LANES = 4, 8
cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=64, bucket_width=1,
                         n_overflow=64)
layout = ht.build_layout(cfg)
t = SimTransport(N_NODES)
state = ht.init_cluster_state(cfg)

# --- insert: every node writes 8 keys through the rpc_handler --------------
klo = jnp.arange(N_NODES * LANES, dtype=jnp.uint32).reshape(N_NODES, LANES)
khi = jnp.zeros_like(klo)
vals = sl._mix32(klo[..., None] + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32))
owner, _, _ = ht.lookup_start(cfg, layout, klo, khi)
handler = ht.make_rpc_handler(cfg, layout)
state, rep, _, _ = rpc.rpc_call(
    t, state, owner, ht.make_record(rpc.OP_INSERT, klo, khi, value=vals),
    handler)
print(f"inserted {int((rep[..., 0] == rpc.ST_OK).sum())} keys")

# --- one-two-sided lookups (Algorithm 1) ------------------------------------
state, _, found, got, _, _, _, _, m = hybrid.hybrid_lookup(
    t, state, klo, khi, cfg, layout, use_onesided=True)
assert bool(found.all()) and np.array_equal(np.asarray(got), np.asarray(vals))
print(f"lookups: {float(m.onesided_success):.0f}/{float(m.total):.0f} "
      f"served by ONE one-sided read; {float(m.rpc_fallback):.0f} chased "
      f"pointers via RPC; {float(m.wire.total_bytes):.0f} wire bytes")

# --- one OCC transaction per lane: read 1 key, write 1 fresh key -----------
state, _, res = tx.run_transactions(
    t, state, cfg, layout,
    read_keys=jnp.stack([klo[:, :, None], khi[:, :, None]], -1),
    write_keys=jnp.stack([klo[:, :, None] + 1000, khi[:, :, None]], -1),
    write_values=vals[:, :, None, :])
print(f"transactions committed: {int(res.committed.sum())}/{res.committed.size} "
      f"in {float(res.round_trips):.0f} pipeline round trips")
