"""Serving scenario: batched prefill + continuous greedy decode with the
Storm-hybrid KV cache, across three architecture families.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig
from repro.configs.registry import get
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.mesh import make_smoke_mesh
from repro.models import api
from repro.models.transformer import RunOptions
from repro.parallel.sharding import SERVE_RULES, Topology, init_params
from repro.serving.decode import kv_mode, make_decode_step, make_prefill

PROMPT, DECODE, B = 32, 12, 2
OPTS = RunOptions(q_block=32, kv_block=32, remat=False)


def serve(arch: str):
    cfg = get(arch).smoke()
    topo = Topology(make_smoke_mesh(), dict(SERVE_RULES))
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    batch = synthetic_batch(cfg, ShapeConfig("s", PROMPT + DECODE, B, "train"),
                            DataConfig(), 0)
    pre = {k: (v[:, :PROMPT] if k == "tokens" else v)
           for k, v in batch.items() if k != "labels"}
    prefill = jax.jit(make_prefill(cfg, topo, PROMPT, OPTS))
    t0 = time.time()
    logits, cache = prefill(params, pre)
    jax.block_until_ready(logits)
    t_pre = time.time() - t0
    for n in ("k", "v", "shared_k", "shared_v"):
        if n in cache:
            cache[n] = jnp.pad(
                cache[n], ((0, 0), (0, 0), (0, DECODE), (0, 0), (0, 0)))
    step = jax.jit(make_decode_step(cfg, topo))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    ids = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(DECODE - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ids.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_dec = time.time() - t0
    mode = ("attention-free" if not cfg.has_attention
            else f"KV {kv_mode(cfg, topo)}-mode")
    print(f"{arch:26s} [{mode:14s}] prefill {t_pre*1e3:7.0f} ms, decode "
          f"{B*(DECODE-1)/max(t_dec,1e-9):6.1f} tok/s, continuation "
          f"{np.stack(ids,1)[0][:6]}")


if __name__ == "__main__":
    for arch in ("granite-moe-1b-a400m", "mamba2-780m", "whisper-medium"):
        serve(arch)
