"""End-to-end driver (deliverable b): train a ~100M-class MoE for a few
hundred steps on the synthetic stream, with checkpoints and eval.

By default runs a reduced granite-family MoE sized to finish on this CPU
container; pass --steps/--width to scale up.

    PYTHONPATH=src python examples/train_moe.py --steps 200
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import ShapeConfig
from repro.configs.registry import get
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.mesh import make_smoke_mesh
from repro.models.transformer import RunOptions
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import Topology
from repro.train.step import TrainHparams, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get("granite-moe-1b-a400m").smoke(),
        d_model=args.width, n_heads=8, n_kv_heads=4, head_dim=16,
        n_layers=4, n_experts=8, top_k=2, d_ff=4 * args.width // 8,
        vocab_size=2048)
    topo = Topology(make_smoke_mesh())
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    hp = TrainHparams(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=30, weight_decay=0.0,
                              grad_clip=0.5),
        opts=RunOptions(q_block=64, kv_block=64, remat=False))
    step_fn = jax.jit(make_train_step(cfg, topo, hp), donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.key(0))
    dc = DataConfig(seed=1)

    t0, losses = time.time(), []
    for s in range(args.steps):
        batch = synthetic_batch(cfg, shape, dc, step=s)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  acc "
                  f"{float(metrics['accuracy']):.3f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
    best = min(losses[-5:])
    print(f"\nloss {losses[0]:.3f} -> {best:.3f} over {args.steps} steps "
          f"({time.time()-t0:.0f}s); MoE dispatched via the Storm hybrid "
          f"(mode chosen by the cost model at trace time)")
    if best >= losses[0]:
        print("WARNING: no improvement at this tiny scale/step budget — "
              "run with --steps 300 for a clear descent")


if __name__ == "__main__":
    main()
