"""OCC transactions under contention: bank-transfer style demo.

Ten accounts, many concurrent transfer transactions per round; Storm's OCC
protocol (execute / lock / validate / commit, Fig. 3) guarantees exactly one
winner per contended account and global balance conservation.

    PYTHONPATH=src python examples/kvstore_tx.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import rpc, slots as sl, tx
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

N_NODES, LANES, ACCOUNTS, ROUNDS = 2, 6, 10, 8
cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=32, bucket_width=2,
                         n_overflow=32)
layout = ht.build_layout(cfg)
t = SimTransport(N_NODES)
state = ht.init_cluster_state(cfg)
handler = ht.make_rpc_handler(cfg, layout)

# accounts 0..9, each starting with balance 100 (word 0 of the value)
acc = jnp.arange(ACCOUNTS, dtype=jnp.uint32)[None].repeat(N_NODES, 0)
acc = acc[:, :LANES] if LANES <= ACCOUNTS else acc
zeros = jnp.zeros_like(acc)
bal0 = jnp.zeros((N_NODES, acc.shape[1], sl.VALUE_WORDS), jnp.uint32
                 ).at[..., 0].set(100)
owner, _, _ = ht.lookup_start(cfg, layout, acc, zeros)
state, rep, _, _ = rpc.rpc_call(
    t, state, owner, ht.make_record(rpc.OP_INSERT, acc, zeros, value=bal0),
    handler)

rng = np.random.RandomState(0)
committed = aborted = 0
for r in range(ROUNDS):
    # every lane tries to bump ONE random account's balance by 1
    target = jnp.asarray(rng.randint(0, ACCOUNTS, (N_NODES, LANES)), jnp.uint32)
    tz = jnp.zeros_like(target)
    # the tx locks the account (read-for-update returns the balance) and the
    # commit installs a new value; exclusivity comes from the OCC protocol
    wk = jnp.stack([target, tz], -1)[:, :, None, :]
    new_vals = (jnp.zeros((N_NODES, LANES, 1, sl.VALUE_WORDS), jnp.uint32)
                .at[..., 0].set(100 + r + 1))
    state, _, res = tx.run_transactions(
        t, state, cfg, layout,
        read_keys=jnp.zeros((N_NODES, LANES, 0, 2), jnp.uint32),
        write_keys=wk, write_values=new_vals)
    c = int(res.committed.sum())
    committed += c
    aborted += res.committed.size - c
print(f"{ROUNDS} rounds x {N_NODES*LANES} lanes: "
      f"{committed} committed, {aborted} aborted (lock/validate conflicts)")

# winners-only accounting: every commit wrote exactly once
state, repl, _, _ = rpc.rpc_call(
    t, state, owner, ht.make_record(rpc.OP_LOOKUP, acc, zeros), handler)
print("final account versions:",
      np.asarray(repl[..., 2]).reshape(-1)[:ACCOUNTS])
print("(even versions = consistent, unlocked; each +2 is one committed write)")
