"""OCC transactions under contention: bank-transfer style demo.

Ten accounts, many concurrent transfer transactions per round; Storm's OCC
protocol (execute / lock / validate / commit, Fig. 3) guarantees exactly one
winner per contended account, and the bounded-retry engine (txloop.tx_loop)
re-runs the losers with randomized-slot backoff until the batch converges —
per-round abort causes are printed so the contention is visible.

    PYTHONPATH=src python examples/kvstore_tx.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import rpc, slots as sl, txloop
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport

N_NODES, LANES, ACCOUNTS, ROUNDS = 2, 6, 10, 8
cfg = ht.HashTableConfig(n_nodes=N_NODES, n_buckets=32, bucket_width=2,
                         n_overflow=32)
layout = ht.build_layout(cfg)
t = SimTransport(N_NODES)
state = ht.init_cluster_state(cfg)
handler = ht.make_rpc_handler(cfg, layout)

# accounts 0..9, each starting with balance 100 (word 0 of the value)
acc = jnp.arange(ACCOUNTS, dtype=jnp.uint32)[None].repeat(N_NODES, 0)
acc = acc[:, :LANES] if LANES <= ACCOUNTS else acc
zeros = jnp.zeros_like(acc)
bal0 = jnp.zeros((N_NODES, acc.shape[1], sl.VALUE_WORDS), jnp.uint32
                 ).at[..., 0].set(100)
owner, _, _ = ht.lookup_start(cfg, layout, acc, zeros)
state, rep, _, _ = rpc.rpc_call(
    t, state, owner, ht.make_record(rpc.OP_INSERT, acc, zeros, value=bal0),
    handler)

rng = np.random.RandomState(0)
# every lane tries to bump ONE random account's balance; heavy contention on
# ten accounts from 12 lanes.  tx_loop retries the losers: each retry round
# re-enables exactly the aborted lanes with permuted send-queue slots.
target = jnp.asarray(rng.randint(0, ACCOUNTS, (N_NODES, LANES)), jnp.uint32)
tz = jnp.zeros_like(target)
wk = jnp.stack([target, tz], -1)[:, :, None, :]
new_vals = (jnp.zeros((N_NODES, LANES, 1, sl.VALUE_WORDS), jnp.uint32)
            .at[..., 0].set(101))
state, _, res = txloop.tx_loop(
    t, state, cfg, layout,
    read_keys=jnp.zeros((N_NODES, LANES, 0, 2), jnp.uint32),
    write_keys=wk, write_values=new_vals, max_rounds=ROUNDS)
committed = int(res.committed.sum())
aborted = res.committed.size - committed
print(f"{ROUNDS} retry rounds x {N_NODES*LANES} lanes: "
      f"{committed} committed, {aborted} never converged")
print("per-round commits:      ", np.asarray(res.round_committed))
print("per-round lock aborts:  ", np.asarray(res.round_abort_lock))
print("per-round valid. aborts:", np.asarray(res.round_abort_validate))
print("single-shot would have committed",
      int(np.asarray(res.round_committed)[0]), "and dropped the rest")

# winners-only accounting: look up ALL ten accounts (from every node — the
# owner's authoritative reply is identical regardless of who asks) and show
# node 0's view of each
acc_all = jnp.arange(ACCOUNTS, dtype=jnp.uint32)[None].repeat(N_NODES, 0)
z_all = jnp.zeros_like(acc_all)
owner_all, _, _ = ht.lookup_start(cfg, layout, acc_all, z_all)
state, repl, _, _ = rpc.rpc_call(
    t, state, owner_all, ht.make_record(rpc.OP_LOOKUP, acc_all, z_all), handler)
print("final account versions:", np.asarray(repl[0, :, 2]))
print("(even versions = consistent, unlocked; each +2 is one committed write)")
