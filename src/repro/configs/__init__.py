from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable  # noqa: F401
