"""whisper-medium [audio] — enc-dec 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865, conv frontend STUB: ``input_specs()`` provides precomputed frame
embeddings (1500, d) per the assignment.  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, encoder_layers=24, encoder_seq=1500,
    source="arXiv:2212.04356")
