"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000, local+global alternating, logit softcap.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=128,
    local_global_pattern=2, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, post_norms=True, embed_scale=True,
    source="arXiv:2408.00118")
