"""Architecture + shape configuration schema.

Every assigned architecture is a frozen ModelConfig; every assigned input
shape a ShapeConfig.  ``smoke()`` derives the reduced same-family config used
by CPU smoke tests; full configs are only ever lowered abstractly
(ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention flavour
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_pattern: int = 0     # 0: all-global; 2: alternate local/global
    post_norms: bool = False          # gemma2 post-attn/post-mlp norms
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    router_renorm: bool = False       # deepseek: softmax-all -> select -> renorm
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): shared transformer block applied every k mamba layers
    shared_attn_every: int = 0
    shared_d_ff: int = 0
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0              # precomputed frame embeddings (stub)
    # vlm (llava): precomputed patch embeddings (stub)
    n_patches: int = 0
    # source provenance (assignment table)
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        """Embedding/LM-head rows padded so the vocab dim always shards over
        the model axis (granite 49155, whisper 51865, mamba 50280 do not
        divide 16).  Pad logits are masked to -inf everywhere."""
        return -(-self.vocab_size // 512) * 512

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def has_attention(self) -> bool:
        return not self.is_ssm

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """May `long_500k` be lowered?  Only SSM/hybrid archs (DESIGN §6)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Total parameter count (approx, for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per = 0
        if self.family in ("ssm", "hybrid"):
            di, H, G, N = self.d_inner, self.ssm_heads, self.ssm_groups, self.ssm_state
            per += 2 * d * di + 2 * d * G * N + d * H     # in projections
            per += self.conv_width * (di + 2 * G * N)     # conv
            per += di * d + di                            # out proj + norm
            per += 3 * H
        if self.has_attention and self.family != "hybrid":
            hd = self.head_dim
            per += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            per += self.n_heads * hd * d
        if self.family == "hybrid" and self.shared_attn_every:
            hd = self.head_dim
            shared = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                      + self.n_heads * hd * d + 3 * d * self.shared_d_ff)
        else:
            shared = 0
        if self.is_moe:
            per += d * self.n_experts                      # router
            per += self.n_experts * 3 * d * self.d_ff      # experts
            per += self.n_shared_experts * 3 * d * self.d_ff
        elif self.family not in ("ssm", "hybrid"):
            per += 3 * d * self.d_ff
        total = emb + L * per + shared
        if self.is_encdec:
            enc_per = (d * self.n_heads * self.head_dim * 2
                       + 2 * d * self.n_kv_heads * self.head_dim
                       + 2 * d * self.d_ff)
            dec_cross = (d * self.n_heads * self.head_dim * 2
                         + 2 * d * self.n_kv_heads * self.head_dim)
            total += self.encoder_layers * enc_per + L * dec_cross
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        inactive = L * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.n_params() - int(inactive)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, self.local_global_pattern or 0,
                         (self.shared_attn_every + 1) if self.shared_attn_every else 0),
            d_model=64,
            n_heads=4, n_kv_heads=(2 if self.n_kv_heads < self.n_heads else 4),
            head_dim=16,
            d_ff=128 if not self.is_moe else 32,
            shared_d_ff=128 if self.shared_d_ff else 0,
            vocab_size=503,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=32,
            sliding_window=64 if self.sliding_window else None,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            n_patches=8 if self.n_patches else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k decode KV cache is "
                       "quadratic-history; skipped per assignment rule")
    return True, ""
