"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, anyres tiling.  Backbone only; the vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings per the assignment.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128, rope_theta=1e6,
    n_patches=2880,   # anyres: up to 5 tiles x 576 patches (stubbed frontend)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf")
