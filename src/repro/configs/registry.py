"""Registry of the 10 assigned architectures (one module per arch)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import (deepseek_moe_16b, gemma2_27b, glm4_9b,
                           granite_moe_1b_a400m, llava_next_mistral_7b,
                           mamba2_780m, qwen15_4b, qwen25_32b, whisper_medium,
                           zamba2_1p2b)

_MODULES = [granite_moe_1b_a400m, deepseek_moe_16b, gemma2_27b, qwen25_32b,
            qwen15_4b, glm4_9b, llava_next_mistral_7b, mamba2_780m,
            zamba2_1p2b, whisper_medium]

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
