from repro.parallel.sharding import Topology, ParamSpec, init_params, abstract_params  # noqa: F401
