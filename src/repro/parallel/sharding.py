"""Logical-axis sharding rules (MaxText-style) + parameter spec trees.

Model code names LOGICAL axes ("batch", "heads", "ff", ...); the Topology maps
them to mesh axes and silently drops any mapping that does not divide the
concrete dimension (e.g. qwen2.5's 40 heads on a 16-wide model axis fall back
to replication — the per-arch table in DESIGN.md §5).

Storm connection: this table is the "region registration" of the dataplane —
it is decided once, off the data path, and produces a STATIC communication
schedule, the moral equivalent of Storm's pre-established RC connections.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> tuple of mesh axis names (applied only if present + divides)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": ("model",),        # decode-time sequence-sharded KV cache
    "vocab": ("model",),
    "embed": (),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "fsdp": ("data",),           # ZeRO-3 weight dim
    "ssm_state": (),
    "conv": (),
}

# Serving: no fsdp (weights kept whole per model-shard, replicated over data)
SERVE_RULES = dict(DEFAULT_RULES, fsdp=(), batch=("pod", "data"))

# §Perf C: sub-scale models (mamba2-780m-class) waste the model axis on
# 96-wide TP matmuls and pay per-layer activation all-reduces.  Wide-DP
# reassigns the model axis to batch + ZeRO: zero TP collectives, params
# sharded over all chips and gathered per layer.
WIDE_DP_RULES = dict(
    DEFAULT_RULES,
    batch=("pod", "data", "model"),
    fsdp=("data", "model"),
    ff=(), heads=(), kv_heads=(), vocab=(), expert=(), kv_seq=(),
)


@dataclasses.dataclass(frozen=True)
class Topology:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def _mesh_axes_for(self, logical: Optional[str], dim: int) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = tuple(a for a in self.rules.get(logical, ()) if a in self.mesh.axis_names)
        # drop trailing axes until the product divides the dimension
        while axes:
            prod = int(np.prod([self.axis_sizes[a] for a in axes]))
            if dim % prod == 0:
                return axes
            axes = axes[:-1]
        return ()

    def spec_for(self, shape: Sequence[int],
                 logical_axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        entries = []
        used: set = set()
        for dim, name in zip(shape, logical_axes):
            axes = tuple(a for a in self._mesh_axes_for(name, dim) if a not in used)
            # re-check divisibility after removing already-used axes
            while axes and dim % int(np.prod([self.axis_sizes[a] for a in axes])) != 0:
                axes = axes[:-1]
            used.update(axes)
            entries.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        return P(*entries)

    def sharding_for(self, shape, logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, logical_axes))

    def constrain(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        return jax.lax.with_sharding_constraint(
            x, self.sharding_for(x.shape, logical_axes))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


# ---------------------------------------------------------------------------
# Parameter specification trees
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"        # "normal" | "zeros" | "ones" | "scaled"
    dtype: Any = jnp.bfloat16
    scale: float = 0.02

    def initialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "scaled":  # 1/sqrt(fan_in) truncated normal
            fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1)
            s = 1.0 / np.sqrt(fan_in)
            return (jax.random.truncated_normal(key, -2, 2, self.shape, jnp.float32)
                    * s).astype(self.dtype)
        return (jax.random.normal(key, self.shape, jnp.float32)
                * self.scale).astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [l.initialize(k) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(spec_tree):
    """ShapeDtypeStructs for the dry-run — full configs never allocate."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec)


def param_shardings(topo: Topology, spec_tree):
    return jax.tree.map(
        lambda s: topo.sharding_for(s.shape, s.logical_axes), spec_tree,
        is_leaf=is_spec)


def param_specs_pspec(topo: Topology, spec_tree):
    return jax.tree.map(
        lambda s: topo.spec_for(s.shape, s.logical_axes), spec_tree,
        is_leaf=is_spec)


def constrain_params(topo: Topology, spec_tree, params):
    return jax.tree.map(
        lambda s, p: jax.lax.with_sharding_constraint(
            p, topo.sharding_for(s.shape, s.logical_axes)),
        spec_tree, params, is_leaf=is_spec)
