"""Minimal fixed-sample stand-in for the tiny slice of the `hypothesis` API
this repo's property tests use (given / settings / strategies.integers,
sampled_from, booleans).

Why: the tier-1 suite must collect and run even on machines where hypothesis
is not installed (the container image does not bake it in, and installing
packages is off-limits).  Rather than skipping the property suites wholesale,
each `@given` test degrades to a deterministic sweep over a small, fixed
sample per strategy — bounds, midpoints, and a couple of pseudo-random
interior points — zipped positionally across strategies.  With hypothesis
present, the real library is used and this module is never imported (see the
try/except import in tests/test_property_storm.py and tests/test_kernels.py).

This is NOT a property-testing framework: no shrinking, no example database,
no stateful testing.  It exists so invariants keep being exercised everywhere.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, samples):
        self.samples = list(samples)


def _dedup(xs):
    seen, out = set(), []
    for x in xs:
        k = (type(x).__name__, x)
        if k not in seen:
            seen.add(k)
            out.append(x)
    return out


class strategies:
    """Fixed-sample counterparts of the strategies the tests use."""

    @staticmethod
    def integers(min_value=0, max_value=100):
        rng = random.Random(min_value * 1000003 + max_value)
        span = max_value - min_value
        picks = [min_value, max_value, min_value + span // 2]
        picks += [min_value + rng.randrange(span + 1) for _ in range(2)]
        return _Strategy(_dedup(picks))

    @staticmethod
    def sampled_from(elements):
        return _Strategy(list(elements))

    @staticmethod
    def booleans():
        return _Strategy([False, True])


st = strategies


def settings(*args, **kwargs):
    """Accepts and ignores hypothesis settings (max_examples, deadline, ...)."""
    if args and callable(args[0]) and not kwargs:
        return args[0]          # bare @settings usage
    return lambda fn: fn


def given(**named_strategies):
    """Run the test once per positional slice across the strategies' fixed
    samples (shorter sample lists wrap around)."""
    names = list(named_strategies)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = max(len(named_strategies[k].samples) for k in names)
            for i in range(n):
                drawn = {k: named_strategies[k].samples[i % len(named_strategies[k].samples)]
                         for k in names}
                fn(*args, **drawn, **kwargs)
        # hide the strategy-filled parameters from pytest's fixture resolver
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in names])
        return wrapper

    return deco
