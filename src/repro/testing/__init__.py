# Test-support utilities (not part of the dataplane).
#   hypothesis_stub — fixed-sample fallback for the hypothesis API so the
#                     property suites still execute where hypothesis is absent
#   workloads       — synthetic workload generators shared by benchmarks/tests
from repro.testing import hypothesis_stub, workloads  # noqa: F401
