"""Shared synthetic workload generators used by both the benchmarks and the
test suite, so the acceptance tests and the benchmarks they guard cannot
silently diverge."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import slots as sl


def value_for(key_lo):
    """Deterministic per-key slot value (VALUE_WORDS uint32 words)."""
    i = jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)
    return sl._mix32(jnp.asarray(key_lo, jnp.uint32)[..., None] + i)


def zipf_write_keys(n_nodes: int, lanes: int, *, n_hot: int = 4,
                    theta: float = 1.5, seed: int = 0, stride: int = 7919):
    """One write key per lane, Zipf(theta)-distributed over n_hot hot keys:
    a few keys absorb most of the write traffic, so lock races abound
    (Storm's contention regime).

    Returns (hot (n_hot,), key_lo (n_nodes, lanes, 1), key_hi same) uint32.
    """
    rng = np.random.RandomState(seed)
    hot = (np.arange(n_hot, dtype=np.uint32) + 1) * np.uint32(stride)
    rank = np.arange(1, n_hot + 1, dtype=np.float64)
    p = 1.0 / rank ** theta
    p /= p.sum()
    pick = rng.choice(n_hot, size=(n_nodes, lanes, 1), p=p)
    klo = jnp.asarray(hot[pick], jnp.uint32)
    return jnp.asarray(hot), klo, jnp.zeros_like(klo)


def distinct_uint32(rng, n, lo=0, hi=2**32 - 2):
    """n DISTINCT uint32 keys uniform over [lo, hi) — via randint + dedup.

    Never use ``rng.choice(big_range, replace=False)`` for this: numpy
    materializes a permutation of the WHOLE population (tens of GB for the
    32-bit key space)."""
    out = np.array([], dtype=np.uint64)
    while out.size < n:
        draw = rng.randint(lo, hi, size=2 * n).astype(np.uint64)
        out = np.unique(np.concatenate([out, draw]))
    rng.shuffle(out)
    return out[:n].astype(np.uint32)
