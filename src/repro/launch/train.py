"""Training launcher: end-to-end driver with checkpoint/restart, straggler
watchdog and deterministic resumable data.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

On a real pod the same entry point runs under multi-host jax.distributed;
here --smoke uses the reduced config on the 1-device mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the local mesh")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--straggler-slack", type=float, default=3.0,
                    help="warn when a step exceeds slack x median")
    args = ap.parse_args()

    from repro.configs import ShapeConfig
    from repro.configs.registry import get
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.launch.mesh import make_smoke_mesh, make_production_mesh
    from repro.models.transformer import RunOptions
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import Topology
    from repro.train.step import (TrainHparams, init_train_state,
                                  make_train_state_specs, make_train_step)

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        mesh = make_smoke_mesh()
        opts = RunOptions(q_block=64, kv_block=64, remat=False)
    else:
        mesh = make_production_mesh()
        opts = RunOptions()
    topo = Topology(mesh)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    hp = TrainHparams(optimizer=AdamWConfig(lr=args.lr),
                      microbatches=args.microbatches, opts=opts)
    step_fn = jax.jit(make_train_step(cfg, topo, hp), donate_argnums=(0,))

    mgr = None
    start = 0
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume:
            try:
                start, state = mgr.restore(topo=topo,
                                           spec_tree=make_train_state_specs(cfg))
                print(f"resumed from step {start}")
            except FileNotFoundError:
                state = init_train_state(cfg, jax.random.key(0))
        else:
            state = init_train_state(cfg, jax.random.key(0))
    else:
        state = init_train_state(cfg, jax.random.key(0))

    dc = DataConfig(seed=0)
    times = []
    for s in range(start, start + args.steps):
        t0 = time.time()
        batch = synthetic_batch(cfg, shape, dc, step=s)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        med = float(np.median(times[-20:]))
        flag = "  [STRAGGLER]" if (len(times) > 3 and dt > args.straggler_slack * med) else ""
        print(f"step {s:5d}  loss {loss:.4f}  gnorm {float(metrics['grad_norm']):.3f}"
              f"  {dt*1e3:7.1f} ms{flag}")
        if mgr and (s + 1) % args.ckpt_every == 0:
            path = mgr.save(s + 1, state)
            print(f"  checkpoint committed: {path.name} "
                  f"(storm tx, latest={mgr.latest_committed_step()})")
    print("done")


if __name__ == "__main__":
    main()
