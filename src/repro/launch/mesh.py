"""Production mesh builders.  A FUNCTION, not a module constant — importing
this module never touches jax device state (the dry-run sets
xla_force_host_platform_device_count before the first jax call)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the cross-pod DP axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
