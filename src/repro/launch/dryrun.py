"""Multi-pod dry-run driver — see DOC below (kept separate because the
XLA_FLAGS env var must be set before anything imports jax)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract the roofline terms.

For each cell this driver:
  1. builds abstract (ShapeDtypeStruct) params / optimizer state / batch /
     cache — NO device allocation for full-size configs,
  2. jits the right step (train_step / prefill / decode_step) with explicit
     in/out shardings,
  3. .lower().compile() — any sharding mismatch, OOM-at-compile or
     unsupported collective is a bug in the system, not in the run,
  4. records memory_analysis(), cost_analysis() and the collective mix
     parsed from the optimized HLO into benchmarks/results/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-moe-1b-a400m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
COLLECTIVE_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
WHILE_RE = re.compile(r"while\(.*body=%?([\w.\-]+)")
TRIP_RE = re.compile(r'known_trip_count"?\s*[:=]\s*\{"?n"?[:=]\s*"?(\d+)')
CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))           # [n_groups, group_size]<=[devices]
    m = GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 0


def parse_collectives(hlo_text: str):
    """Per-device output bytes + replica-group sizes of every collective in
    the optimized (post-SPMD) HLO, with while-loop TRIP COUNTS applied
    (XLA text places a scanned layer's collectives once inside the loop
    body; `known_trip_count` gives the multiplier)."""
    comp_collectives = {}   # comp -> [(op, bytes, group)]
    comp_whiles = {}        # comp -> [(body_comp, trip)]
    comp_calls = {}         # comp -> [callee]
    cur = "__top__"
    for line in hlo_text.splitlines():
        mc = COMP_RE.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if mc:
            cur = mc.group(1)
            continue
        mw = WHILE_RE.search(line)
        if mw:
            mt = TRIP_RE.search(line)
            trip = int(mt.group(1)) if mt else 1
            comp_whiles.setdefault(cur, []).append((mw.group(1), trip))
            continue
        m = COLLECTIVE_RE.search(line)
        if m:
            type_str, op = m.groups()
            comp_collectives.setdefault(cur, []).append(
                (op, _shape_bytes(type_str), _group_size(line)))
            continue
        mcall = CALL_RE.search(line)
        if mcall and ("fusion(" in line or "call(" in line
                      or "conditional(" in line):
            comp_calls.setdefault(cur, []).append(mcall.group(1))

    # propagate multipliers from every root (computations not named as a
    # while body get multiplier 1 — entry, conditions, fusions reached by
    # calls inherit the caller's multiplier)
    bodies = {b for ws in comp_whiles.values() for b, _ in ws}
    mult = {c: 1 for c in (set(comp_collectives) | set(comp_whiles)
                           | set(comp_calls)) if c not in bodies}
    frontier = list(mult)
    seen = set(frontier)
    while frontier:
        c = frontier.pop()
        for body, trip in comp_whiles.get(c, []):
            m = mult.get(c, 1) * max(trip, 1)
            if mult.get(body, 0) < m:
                mult[body] = m
                if body not in seen or True:
                    frontier.append(body)
        for callee in comp_calls.get(c, []):
            m = mult.get(c, 1)
            if mult.get(callee, 0) < m:
                mult[callee] = m
                frontier.append(callee)

    out = {}
    for comp, items in comp_collectives.items():
        k = mult.get(comp, 1)
        for op, b, g in items:
            d = out.setdefault(op, {"count": 0, "bytes": 0, "by_group": {}})
            d["count"] += k
            d["bytes"] += b * k
            gk = str(g)
            d["by_group"][gk] = d["by_group"].get(gk, 0) + b * k
    return out


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               opt: str = "baseline", moe_mode: str = "auto"):
    """Returns (jitted_fn, example_args (abstract), meta).

    opt="tuned" applies the §Perf exact-equivalent optimizations:
    pad_heads (A1) and wide-DP rules for sub-scale SSMs (C1)."""
    from repro.configs import SHAPES, shape_applicable
    from repro.configs.registry import get
    from repro.data.pipeline import batch_specs
    from repro.launch.mesh import make_production_mesh
    from repro.models import api
    from repro.models.transformer import RunOptions
    from repro.optim.adamw import opt_state_specs
    from repro.parallel.sharding import (DEFAULT_RULES, SERVE_RULES,
                                         WIDE_DP_RULES, Topology,
                                         abstract_params, param_shardings)
    from repro.serving.decode import (cache_abstract, cache_shardings,
                                      make_decode_step, make_prefill)
    from repro.train.step import TrainHparams, make_train_step

    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pspecs = api.param_specs(cfg)
    tuned = opt == "tuned"
    # §Perf C: sub-scale models (d_model <= 1536) waste the model axis on
    # narrow TP; widen DP instead (experts/vocab replicated, ZeRO over all)
    wide_dp = tuned and cfg.d_model <= 1536 and cfg.family in ("ssm", "moe")

    if shape.kind == "train":
        rules = WIDE_DP_RULES if wide_dp else DEFAULT_RULES
        topo = Topology(mesh, dict(rules))
        hp = TrainHparams(opts=RunOptions(remat=True, pad_heads=tuned,
                                          moe_mode=moe_mode))
        step = make_train_step(cfg, topo, hp)
        ospecs = opt_state_specs(pspecs)
        state_abs = {"params": abstract_params(pspecs),
                     "opt": abstract_params(ospecs)}
        state_sh = {"params": param_shardings(topo, pspecs),
                    "opt": param_shardings(topo, ospecs)}
        batch_abs = batch_specs(cfg, shape)
        batch_sh = {k: topo.sharding_for(v.shape, ("batch",) + (None,) * (len(v.shape) - 1))
                    for k, v in batch_abs.items()}
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        return fn, (state_abs, batch_abs), {"kind": "train"}

    topo = Topology(mesh, dict(SERVE_RULES))
    params_abs = abstract_params(pspecs)
    params_sh = param_shardings(topo, pspecs)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "prefill":
        prefill = make_prefill(cfg, topo, S, RunOptions(remat=False))
        batch_abs = batch_specs(cfg, shape)
        batch_sh = {k: topo.sharding_for(v.shape, ("batch",) + (None,) * (len(v.shape) - 1))
                    for k, v in batch_abs.items()}
        cache_sh = cache_shardings(cfg, topo, B, S)
        logit_sh = topo.sharding_for((B, cfg.vocab_padded), ("batch", "vocab"))
        fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh),
                     out_shardings=(logit_sh, cache_sh))
        return fn, (params_abs, batch_abs), {"kind": "prefill"}

    # decode: one new token against a cache of S
    step = make_decode_step(cfg, topo)
    cache_abs = cache_abstract(cfg, topo, B, S)
    cache_sh = cache_shardings(cfg, topo, B, S)
    tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = topo.sharding_for((B,), ("batch",))
    logit_sh = topo.sharding_for((B, cfg.vocab_padded), ("batch", "vocab"))
    fn = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh),
                 out_shardings=(logit_sh, cache_sh), donate_argnums=(1,))
    return fn, (params_abs, cache_abs, tok_abs), {"kind": "decode"}


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             opt: str = "baseline", moe_mode: str = "auto", tag_suffix: str = ""):
    tag = f"{arch}__{shape_name}__{mesh_kind}{tag_suffix}"
    out_path = RESULTS / f"{tag}.json"
    if out_path.exists() and not force:
        print(f"[skip-cached] {tag}")
        return json.loads(out_path.read_text())
    RESULTS.mkdir(parents=True, exist_ok=True)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "opt": opt, "moe_mode": moe_mode}
    t0 = time.time()
    try:
        fn, args, meta = build_cell(arch, shape_name, mesh_kind == "multi",
                                    opt=opt, moe_mode=moe_mode)
        rec.update(meta)
        if fn is None:
            rec["status"] = "skipped"
            out_path.write_text(json.dumps(rec, indent=2))
            print(f"[skipped ] {tag}: {meta['skipped']}")
            return rec
        t1 = time.time()
        lowered = fn.lower(*args)
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        try:
            rec["memory"]["peak"] = int(mem.peak_memory_in_bytes)
        except Exception:
            pass
        rec["cost"] = {k: float(v) for k, v in dict(cost or {}).items()
                       if isinstance(v, (int, float)) and (
                           k in ("flops", "bytes accessed")
                           or k.startswith("bytes accessed"))}
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        rec["timings"] = {"build_s": round(t1 - t0, 2),
                          "lower_s": round(t2 - t1, 2),
                          "compile_s": round(t3 - t2, 2)}
        rec["status"] = "ok"
        print(f"[ok      ] {tag}: lower {t2-t1:.1f}s compile {t3-t2:.1f}s "
              f"flops={rec['cost'].get('flops', 0):.3e}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[ERROR   ] {tag}: {type(e).__name__}: {str(e)[:200]}")
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="baseline", choices=["baseline", "tuned"])
    ap.add_argument("--moe-mode", default="auto",
                    choices=["auto", "rpc", "onesided"])
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (e.g. __tuned)")
    args = ap.parse_args()

    from repro.configs import SHAPES
    from repro.configs.registry import ARCHS

    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    n_ok = n_err = n_skip = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force,
                               opt=args.opt, moe_mode=args.moe_mode,
                               tag_suffix=args.tag)
                s = rec.get("status")
                n_ok += s == "ok"
                n_err += s == "error"
                n_skip += s == "skipped"
    print(f"\ndone: {n_ok} ok, {n_skip} skipped-by-rule, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
