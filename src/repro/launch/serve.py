"""Serving launcher: batched prefill + continuous greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --smoke --batch 4 --prompt 32 --decode 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--decode", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import ShapeConfig
    from repro.configs.registry import get
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.launch.mesh import make_smoke_mesh, make_production_mesh
    from repro.models import api
    from repro.models.transformer import RunOptions
    from repro.parallel.sharding import SERVE_RULES, Topology, init_params
    from repro.serving.decode import make_decode_step, make_prefill

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        mesh = make_smoke_mesh()
    else:
        mesh = make_production_mesh()
    topo = Topology(mesh, dict(SERVE_RULES))
    opts = RunOptions(q_block=64, kv_block=64, remat=False)

    params = init_params(api.param_specs(cfg), jax.random.key(0))
    total = args.prompt + args.decode
    shape = ShapeConfig("serve", total, args.batch, "train")
    batch = synthetic_batch(cfg, shape, DataConfig(), 0)
    pre_batch = {k: (v[:, :args.prompt] if k in ("tokens",) else v)
                 for k, v in batch.items() if k != "labels"}

    prefill = jax.jit(make_prefill(cfg, topo, args.prompt, opts))
    t0 = time.time()
    logits, cache = prefill(params, pre_batch)
    logits.block_until_ready()
    print(f"prefill: {args.batch}x{args.prompt} tokens in "
          f"{(time.time()-t0)*1e3:.1f} ms")

    # grow KV space for the decode phase
    for n in ("k", "v", "shared_k", "shared_v"):
        if n in cache:
            c = cache[n]
            cache[n] = jnp.pad(
                c, ((0, 0), (0, 0), (0, args.decode), (0, 0), (0, 0)))

    step = jax.jit(make_decode_step(cfg, topo))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.decode - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.time() - t0
    toks = args.batch * (args.decode - 1)
    print(f"decode: {toks} tokens in {dt*1e3:.1f} ms "
          f"({toks/max(dt,1e-9):.1f} tok/s greedy)")
    print("sample continuation ids:", np.stack(outs, 1)[0][:12])


if __name__ == "__main__":
    main()
