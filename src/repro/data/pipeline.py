"""Deterministic, resumable data pipeline.

Batches are a pure function of (seed, step) — restart at step k reproduces
the exact token stream (the checkpoint only needs to store the step), and
any host can materialize exactly its shard (multi-host friendly: build with
jax.make_array_from_callback against the batch sharding).

Synthetic stream: a mixing hash over (seed, step, position) modulo vocab,
with a repeated-ngram structure so the LM loss actually decreases (the model
can learn local structure) — useful for the end-to-end training example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    ngram: int = 8          # period of the learnable repetition


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 16)) * np.uint64(0x85EBCA6B)
    x = (x ^ (x >> 13)) * np.uint64(0xC2B2AE35)
    return x ^ (x >> 16)


def synthetic_tokens(dc: DataConfig, step: int, batch: int, seq: int,
                     vocab: int) -> np.ndarray:
    b = np.arange(batch, dtype=np.uint64)[:, None]
    s = np.arange(seq, dtype=np.uint64)[None, :]
    base = _mix(np.uint64(dc.seed) * np.uint64(1_000_003)
                + np.uint64(step) * np.uint64(65_537) + b * np.uint64(131)
                + (s // np.uint64(dc.ngram)))
    tok = (base + s % np.uint64(dc.ngram)) % np.uint64(max(vocab - 2, 1))
    return tok.astype(np.int32) + 1          # avoid 0 (pad id)


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, dc: DataConfig,
                    step: int) -> Dict[str, jnp.ndarray]:
    toks = synthetic_tokens(dc, step, shape.global_batch, shape.seq_len + 1,
                            cfg.vocab_size)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }
    if cfg.family == "audio":
        rng = np.random.RandomState(dc.seed * 7919 + step)
        batch["frames"] = jnp.asarray(
            rng.randn(shape.global_batch, cfg.encoder_seq,
                      cfg.d_model).astype(np.float32) * 0.02, jnp.bfloat16)
    if cfg.family == "vlm":
        rng = np.random.RandomState(dc.seed * 104729 + step)
        n_p = min(cfg.n_patches, shape.seq_len)
        batch["patch_embeds"] = jnp.asarray(
            rng.randn(shape.global_batch, n_p,
                      cfg.d_model).astype(np.float32) * 0.02, jnp.bfloat16)
    return batch


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the dry-run (train/prefill kinds)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, min(cfg.n_patches, S), cfg.d_model), jnp.bfloat16)
    return specs
