from repro.data.pipeline import DataConfig, synthetic_batch, batch_specs  # noqa: F401
