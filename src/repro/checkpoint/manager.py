"""Fault-tolerant checkpointing with elastic restore.

Design (DESIGN.md §7):
  * step-atomic: write to ``step_<n>.tmp/``, fsync, then COMMIT by renaming
    — a crash mid-write leaves the previous checkpoint intact;
  * the commit record is a Storm transaction against a (simulated) metadata
    KV store: the manifest pointer flips only if the OCC commit succeeds —
    the paper's transactional dataplane guarding the training job's control
    plane;
  * elastic restore: arrays are saved UNSHARDED-logical (np arrays +
    logical axis names).  Restore takes ANY Topology and re-device_puts with
    the new mesh's shardings — pod counts can change between runs;
  * resumable data: only the step index is stored; the pipeline is a pure
    function of (seed, step).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slots as sl
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.parallel.sharding import Topology, is_spec


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = tree
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        # Storm-backed commit registry (simulated single-node control plane)
        self._ht_cfg = ht.HashTableConfig(n_nodes=1, n_buckets=64,
                                          bucket_width=2, n_overflow=64)
        self._ht_layout = ht.build_layout(self._ht_cfg)
        self._t = SimTransport(1)
        self._meta_state = ht.init_cluster_state(self._ht_cfg)

    # -- Storm commit record ------------------------------------------------
    def _commit_record(self, step: int) -> bool:
        """Flip the manifest pointer via an OCC transaction (key=0 holds the
        latest step).  Returns committed?"""
        key = jnp.zeros((1, 1, 1), jnp.uint32)          # manifest key
        write_keys = jnp.stack([key, key], axis=-1)[..., 0, :].reshape(1, 1, 1, 2)
        val = jnp.zeros((1, 1, 1, sl.VALUE_WORDS), jnp.uint32)
        val = val.at[..., 0].set(step)
        self._meta_state, _, res = txm.run_transactions(
            self._t, self._meta_state, self._ht_cfg, self._ht_layout,
            read_keys=jnp.zeros((1, 1, 0, 2), jnp.uint32),
            write_keys=write_keys, write_values=val)
        return bool(res.committed.all())

    def latest_committed_step(self) -> Optional[int]:
        from repro.core import hybrid as hy
        key = jnp.zeros((1, 1), jnp.uint32)
        self._meta_state, _, found, value, *_ = hy.hybrid_lookup(
            self._t, self._meta_state, key, key, self._ht_cfg, self._ht_layout)
        if bool(found[0, 0]):
            return int(value[0, 0, 0])
        return None

    # -- save / restore ------------------------------------------------------
    def save(self, step: int, state, spec_tree=None) -> pathlib.Path:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        flat = _flatten(state)
        manifest = {"step": step, "arrays": {}}
        for k, v in flat.items():
            arr = np.asarray(jax.device_get(v))
            if arr.dtype == jnp.bfloat16:
                arr = arr.astype(np.float32)
                manifest["arrays"][k] = {"dtype": "bfloat16"}
            else:
                manifest["arrays"][k] = {"dtype": str(arr.dtype)}
            np.save(tmp / (k.replace("/", "__") + ".npy"), arr)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        fd = os.open(tmp, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
        os.rename(tmp, final)                       # atomic commit on POSIX
        if not self._commit_record(step):
            raise RuntimeError("Storm commit record aborted (concurrent writer)")
        self._gc()
        return final

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if not c.name.endswith(".tmp")]
        for old in ckpts[:-self.keep]:
            shutil.rmtree(old)

    def restore(self, step: Optional[int] = None, *,
                topo: Optional[Topology] = None, spec_tree=None):
        """Restore to the CURRENT topology (elastic: mesh may differ from
        the one that saved).  Returns (step, state)."""
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if not c.name.endswith(".tmp")]
        if not ckpts:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = (self.dir / f"step_{step:08d}") if step is not None else ckpts[-1]
        manifest = json.loads((path / "manifest.json").read_text())
        flat = {}
        spec_flat = _flatten(spec_tree) if spec_tree is not None else {}
        for k, meta in manifest["arrays"].items():
            arr = np.load(path / (k.replace("/", "__") + ".npy"))
            if meta["dtype"] == "bfloat16":
                arr = jnp.asarray(arr, jnp.bfloat16)
            else:
                arr = jnp.asarray(arr)
            if topo is not None and k in spec_flat and is_spec(spec_flat[k]):
                s = spec_flat[k]
                arr = jax.device_put(arr, topo.sharding_for(s.shape,
                                                            s.logical_axes))
            flat[k] = arr
        return manifest["step"], _unflatten(flat)
