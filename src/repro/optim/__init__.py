from repro.optim.adamw import AdamWConfig, init_opt_state, opt_state_specs, apply_updates  # noqa: F401
