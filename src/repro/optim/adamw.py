"""AdamW with fp32 master weights + moments, sharded exactly like the params
(ZeRO-style via the fsdp axis on the weight specs).  bf16 params are derived
from the master copy each step; gradient clipping is by global norm (the
norm reduction crosses every sharded axis — XLA partitions it into local
partials + one small all-reduce)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, is_spec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def opt_state_specs(param_spec_tree):
    """Master/m/v get the same logical axes as the param, fp32."""
    def f32spec(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, dtype=jnp.float32, init="zeros")
    return {
        "master": jax.tree.map(
            lambda s: dataclasses.replace(s, dtype=jnp.float32),
            param_spec_tree, is_leaf=is_spec),
        "m": jax.tree.map(f32spec, param_spec_tree, is_leaf=is_spec),
        "v": jax.tree.map(f32spec, param_spec_tree, is_leaf=is_spec),
        "step": ParamSpec((), (), "zeros", jnp.int32),
    }


def init_opt_state(params):
    return {
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def apply_updates(cfg: AdamWConfig, grads, opt_state, param_dtype=jnp.bfloat16):
    """Returns (new_params_bf16, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    # global-norm clip in fp32
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        w = w - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
        return m, v, w

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                       opt_state["master"])
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    w = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    params = jax.tree.map(lambda x: x.astype(param_dtype), w)
    new_state = {"master": w, "m": m, "v": v, "step": step}
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
