"""Batched MICA bucket probe as a Pallas TPU kernel — the one-sided lookup
hot path (`remote_read` + `lookup_end`) fused on-chip.

TPU-native structure: the bucket indices are SCALAR-PREFETCHED and consumed
by the arena BlockSpec index_map, so the sequential grid streams exactly the
bucket lines the keys hash to (the NIC's gather, expressed as data-dependent
block fetching).  One grid step = one key: load the bucket's slots, compare
key / version-parity / lock, select the value.

Layout contract: the arena's slot region starts at word 0 (hashtable
build_layout registers "slots" first) and buckets are bucket_width slots of
SLOT_WORDS words -> the arena can be viewed (n_buckets, width*SLOT_WORDS).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import slots as sl

# reply words: [found, version, value...]
REPLY_WORDS = 2 + sl.VALUE_WORDS


def _kernel(bucket_idx_ref, key_lo_ref, key_hi_ref, bucket_ref, out_ref, *,
            width: int):
    b = pl.program_id(0)
    key_lo = key_lo_ref[b]
    key_hi = key_hi_ref[b]
    slots_ = bucket_ref[0].reshape(width, sl.SLOT_WORDS)
    ok = ((slots_[:, sl.KEY_LO] == key_lo)
          & (slots_[:, sl.KEY_HI] == key_hi)
          & (slots_[:, sl.VERSION] % 2 == 0)
          & (slots_[:, sl.LOCK] == 0))
    found = jnp.any(ok)
    # first matching slot (argmax on bool)
    idx = jnp.argmax(ok.astype(jnp.int32))
    slot = slots_[idx]
    out = jnp.zeros((REPLY_WORDS,), jnp.uint32)
    out = out.at[0].set(found.astype(jnp.uint32))
    out = out.at[1].set(slot[sl.VERSION])
    val = jnp.where(found, slot[sl.VALUE0:], jnp.zeros((sl.VALUE_WORDS,), jnp.uint32))
    out = out.at[2:].set(val)
    out_ref[0] = out


@functools.partial(jax.jit,
                   static_argnames=("width", "interpret"))
def hash_probe(arena, bucket_idx, key_lo, key_hi, *, width: int,
               interpret: bool = False):
    """arena: (n_words,) uint32 with slots at word 0; bucket_idx: (B,) int32;
    key_lo/key_hi: (B,) uint32.  Returns (B, REPLY_WORDS) uint32."""
    B = bucket_idx.shape[0]
    line = width * sl.SLOT_WORDS
    n_buckets = arena.shape[0] // line
    arena2d = arena[:n_buckets * line].reshape(n_buckets, line)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, line), lambda b, bidx, klo, khi: (bidx[b], 0)),
        ],
        out_specs=pl.BlockSpec((1, REPLY_WORDS), lambda b, *_: (b, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, REPLY_WORDS), jnp.uint32),
        interpret=interpret,
    )(bucket_idx.astype(jnp.int32), key_lo.astype(jnp.uint32),
      key_hi.astype(jnp.uint32), arena2d)
