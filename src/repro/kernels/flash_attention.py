"""Flash attention as a Pallas TPU kernel.

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost so the online-softmax
state (m, l, acc) lives in VMEM scratch across kv iterations.  Causal /
sliding-window blocks outside the mask are skipped with pl.when (the pair
schedule of models.layers.block_attention realized on-chip).  GQA is handled
by the K/V index_map (q head h reads kv head h // group).

Block shapes are MXU-aligned (q_block x head_dim and kv_block x head_dim
tiles, head_dim 64/128 in every assigned config; defaults 128x128).
VMEM working set per step:
    q (qb x D) + k,v (kb x D each) + acc (qb x D f32) + scores (qb x kb f32)
    = 128x128 x (2+2+2)B + 128x128x4 x2 = ~230 KiB  << 16 MiB VMEM.

Validated against kernels.ref.attention_ref in interpret mode (CPU); on TPU
the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], q_block: int, kv_block: int,
            n_kv: int, seq_k: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = i * q_block
    k_lo = j * kv_block
    needed = True
    if causal:
        needed = jnp.asarray(k_lo <= q_lo + q_block - 1)
    if window is not None:
        needed = needed & jnp.asarray(
            k_lo + kv_block - 1 >= q_lo - (window - 1))

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (qb, D)
        k = k_ref[0].astype(jnp.float32)            # (kb, D)
        v = v_ref[0]                                # (kb, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_lo + lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k_lo + lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = kpos < seq_k
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = corr[:, None] * acc_ref[...] + pv
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "q_block",
                              "kv_block", "group", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=None, softcap=None,
                         q_block=128, kv_block=128, group=1,
                         interpret=False):
    """q: (BHq, Sq, D); k/v: (BHkv, Sk, D) with BHq == BHkv * group.
    Heads-major layout; see ops.flash_attention for the (B,S,H,D) wrapper."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    pad_q = (-Sq) % qb
    pad_k = (-Sk) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    n_q = (Sq + pad_q) // qb
    n_kv = (Sk + pad_k) // kb
    scale = D ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        q_block=qb, kv_block=kb, n_kv=n_kv, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, D), jnp.float32),   # acc
            pltpu.VMEM((qb,), jnp.float32),     # m
            pltpu.VMEM((qb,), jnp.float32),     # l
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
