"""Pure-jnp oracles for every Pallas kernel (CI compares interpret-mode
kernels against these across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import slots as sl
from repro.models.layers import attention_ref  # noqa: F401  (flash oracle)
from repro.models.mamba2 import ssd_chunked  # noqa: F401


def attention_ref_bhsd(q, k, v, *, causal=True, window=None, softcap=None):
    """(BH, S, D) layout oracle wrapping models.layers.attention_ref."""
    BH, Sq, D = q.shape
    BHkv = k.shape[0]
    g = BH // BHkv
    qb = q.reshape(BHkv, g, Sq, D).transpose(0, 2, 1, 3)[None]
    kb = k.transpose(1, 0, 2)[None]
    vb = v.transpose(1, 0, 2)[None]
    # attention_ref expects (B, S, H, D)
    q4 = q.reshape(1, BH, Sq, D).transpose(0, 2, 1, 3)
    k4 = k.reshape(1, BHkv, -1, D).transpose(0, 2, 1, 3)
    v4 = v.reshape(1, BHkv, -1, D).transpose(0, 2, 1, 3)
    out = attention_ref(q4, k4, v4, causal=causal, window=window,
                        attn_softcap=softcap)
    return out.transpose(0, 2, 1, 3).reshape(BH, Sq, D)


def hash_probe_ref(arena, bucket_idx, key_lo, key_hi, *, width: int):
    """Oracle for kernels.hash_probe: probe bucket slots, no chain."""
    line = width * sl.SLOT_WORDS

    def one(bi, klo, khi):
        base = bi.astype(jnp.int32) * line
        buf = jax.lax.dynamic_slice(arena, (base,), (line,))
        slots_ = buf.reshape(width, sl.SLOT_WORDS)
        ok = ((slots_[:, sl.KEY_LO] == klo)
              & (slots_[:, sl.KEY_HI] == khi)
              & (slots_[:, sl.VERSION] % 2 == 0)
              & (slots_[:, sl.LOCK] == 0))
        found = jnp.any(ok)
        idx = jnp.argmax(ok.astype(jnp.int32))
        slot = slots_[idx]
        val = jnp.where(found, slot[sl.VALUE0:],
                        jnp.zeros((sl.VALUE_WORDS,), jnp.uint32))
        return jnp.concatenate([
            jnp.stack([found.astype(jnp.uint32), slot[sl.VERSION]]), val])

    return jax.vmap(one)(bucket_idx, key_lo, key_hi)


def ssd_scan_ref(xdt, dA, Bc, Cc):
    """Oracle for kernels.ssd_scan: the exact per-timestep recurrence
        h_t = exp(dA_t) h_{t-1} + B_t xdt_t ;  y_t = C_t h_t
    (identical semantics to models.mamba2.ssd_chunked with xdt = x*dt and
    dA = dt*A folded in by the caller).

    xdt: (B, nc, Q, H, P) f32; dA: (B, nc, Q, H); Bc/Cc: (B, nc, Q, N).
    """
    B, nc, Q, H, P = xdt.shape
    S = nc * Q
    flat = lambda t: t.reshape((B, S) + t.shape[3:])
    state = jnp.zeros((B, H, Bc.shape[-1], P), jnp.float32)
    xf, df = flat(xdt), flat(dA)
    Bf, Cf = flat(Bc), flat(Cc)

    def step(state, t):
        x_t, dA_t, B_t, C_t = t
        decay = jnp.exp(dA_t)                                    # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", B_t, x_t)
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bn,bhnp->bhp", C_t, state)
        return state, y_t

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(df, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc, Q, H, P)
    return y, state
