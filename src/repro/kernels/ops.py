"""jit'd public wrappers around the Pallas kernels.

Layout adapters + the use_pallas switch: on CPU (this container) the
reference path or interpret mode runs; on TPU the same call sites lower the
Mosaic kernels.  `repro.models.layers.block_attention` / `mamba2.ssd_chunked`
are the jnp paths the dry-run lowers; these wrappers are the drop-in
kernel-backed equivalents.
"""
from __future__ import annotations


import jax

from repro.kernels import flash_attention as fa
from repro.kernels import hash_probe as hp
from repro.kernels import ssd_scan as ss
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_block=128, kv_block=128, use_pallas=None,
                    interpret=None):
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D) — model layout."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    interpret = (not on_tpu()) if interpret is None else interpret
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if not use_pallas and not interpret:
        from repro.models.layers import block_attention
        return block_attention(q, k, v, causal=causal, window=window,
                               attn_softcap=softcap, q_block=q_block,
                               kv_block=kv_block)
    g = Hq // Hkv
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    out = fa.flash_attention_bhsd(qh, kh, vh, causal=causal, window=window,
                                  softcap=softcap, q_block=q_block,
                                  kv_block=kv_block, group=g,
                                  interpret=interpret)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)


def hash_probe(arena, bucket_idx, key_lo, key_hi, *, width,
               use_pallas=None, interpret=None):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    interpret = (not on_tpu()) if interpret is None else interpret
    if not use_pallas and not interpret:
        return ref.hash_probe_ref(arena, bucket_idx, key_lo, key_hi,
                                  width=width)
    return hp.hash_probe(arena, bucket_idx, key_lo, key_hi, width=width,
                         interpret=interpret)


def ssd_scan(xdt, dA, Bc, Cc, *, h_tile=4, use_pallas=None, interpret=None):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    interpret = (not on_tpu()) if interpret is None else interpret
    if not use_pallas and not interpret:
        return ref.ssd_scan_ref(xdt, dA, Bc, Cc)
    return ss.ssd_scan(xdt, dA, Bc, Cc, h_tile=h_tile, interpret=interpret)
