"""Mamba2 SSD chunk scan as a Pallas TPU kernel.

Grid: (B, n_head_tiles, n_chunks) — chunks innermost so the inter-chunk
state (h_tile, N, P) persists in VMEM scratch across the sequential grid
(the recurrence never leaves the chip; only per-chunk inputs stream in).

Per step, for its head tile:
    cum   = cumsum(dA)                      (Q, h)
    CB    = C @ B^T                         (Q, Q)   MXU
    y     = (CB * decay * causal) @ xdt     (Q, h, P) MXU per head
    y    += (C @ state) * exp(cum)          MXU
    state = exp(cum_Q) * state + (B * dec_end)^T @ xdt

VMEM working set (Q=128, h_tile=4, N=128, P=64):
    xdt 128*4*64*4B + CB 128*128*4B + state 4*128*64*4B = ~0.5 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(xdt_ref, dA_ref, B_ref, C_ref, y_ref, state_out_ref, state_ref, *,
            Q: int, n_chunks: int, h_tile: int, N: int, P: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0, 0].astype(jnp.float32)        # (Q, h, P)
    dA = dA_ref[0, 0].astype(jnp.float32)          # (Q, h)
    Bc = B_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Cc = C_ref[0, 0].astype(jnp.float32)           # (Q, N)

    cum = jnp.cumsum(dA, axis=0)                   # (Q, h)
    CB = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    qi = lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = qi >= ki

    y = jnp.zeros((Q, h_tile, P), jnp.float32)
    state_new = jnp.zeros((h_tile, N, P), jnp.float32)
    for h in range(h_tile):                        # static unroll over tile
        delta = cum[:, None, h] - cum[None, :, h]
        delta = jnp.where(causal, delta, NEG)
        scores = CB * jnp.exp(delta)               # (Q, Q)
        yh = jax.lax.dot_general(scores, xdt[:, h], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        st = state_ref[h]                          # (N, P)
        y_off = jax.lax.dot_general(Cc, st, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        yh = yh + y_off * jnp.exp(cum[:, h])[:, None]
        y = y.at[:, h].set(yh)
        dec_end = jnp.exp(cum[-1, h] - cum[:, h])  # (Q,)
        upd = jax.lax.dot_general(
            Bc * dec_end[:, None], xdt[:, h], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)    # (N, P)
        state_new = state_new.at[h].set(jnp.exp(cum[-1, h]) * st + upd)
    state_ref[...] = state_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == n_chunks - 1)
    def _emit_state():
        state_out_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("h_tile", "interpret"))
def ssd_scan(xdt, dA, Bc, Cc, *, h_tile: int = 4, interpret: bool = False):
    """xdt: (B, nc, Q, H, P) f32 (= x * dt); dA: (B, nc, Q, H) f32;
    Bc/Cc: (B, nc, Q, N) f32.
    Returns (y (B, nc, Q, H, P) f32, final_state (B, H, N, P) f32)."""
    B, nc, Q, H, P = xdt.shape
    N = Bc.shape[-1]
    assert H % h_tile == 0, (H, h_tile)
    nh = H // h_tile

    kernel = functools.partial(_kernel, Q=Q, n_chunks=nc, h_tile=h_tile,
                               N=N, P=P)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, h_tile, P),
                         lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, h_tile), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, h_tile, P),
                         lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, h_tile, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h_tile, N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, dA, Bc, Cc)
    return y, state
