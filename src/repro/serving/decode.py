"""Serving: prefill + single-token decode with the Storm-hybrid KV cache.

The KV cache is the framework's flagship "remote data structure" (DESIGN §3):
one contiguous region per layer, sharded over the `model` axis.  Two access
modes per architecture, chosen STRUCTURALLY by the sharding that is legal and
priced by the cost model:

  * heads mode ("one-sided"):  kv-heads shard over `model`; the decode
    attention runs entirely locally per shard — the query's shard reads
    exactly its heads' K/V rows.  Needs n_kv % tp == 0
    (deepseek 16, gemma2 16, whisper 16, zamba2 32).
  * seq mode ("RPC"): the cache shards over SEQUENCE; the query is broadcast
    to every shard, each computes partial flash-decode statistics (m, l, o)
    over its local slice — compute-at-the-data — and a psum combines.
    This is Storm's write-based RPC pattern: tiny request (q) out, tiny
    reply (partials) back, owner does the walking.
    (granite kv=8, qwen2.5 kv=8, qwen1.5 kv=20, glm4 kv=2, llava kv=8.)

KV append for the new token is a one-sided WRITE at a static offset
(scatter at `len`), never a handler.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.embedding import embed_lookup
from repro.models.moe import moe_ffn
from repro.models.transformer import RunOptions
from repro.parallel.sharding import Topology


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------
def kv_mode(cfg: ModelConfig, topo: Topology) -> str:
    tp = topo.axis_sizes.get("model", 1)
    if tp == 1:
        return "heads"
    return "heads" if (cfg.n_kv_heads % tp == 0 and cfg.n_heads % tp == 0) \
        else "seq"


def _kv_axes(mode: str):
    return ((None, "batch", None, "kv_heads", None) if mode == "heads"
            else (None, "batch", "kv_seq", None, None))


def cache_specs(cfg: ModelConfig, topo: Topology, B: int, S: int):
    """Returns {name: (shape, logical_axes, dtype)} describing the cache."""
    out: Dict[str, Tuple] = {"len": ((B,), ("batch",), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        mode = kv_mode(cfg, topo)
        shp = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
        out["k"] = (shp, _kv_axes(mode), jnp.bfloat16)
        out["v"] = (shp, _kv_axes(mode), jnp.bfloat16)
    if cfg.family in ("ssm", "hybrid"):
        nl = cfg.n_layers
        di, GN = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
        K = cfg.conv_width
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        out["conv_x"] = ((nl, B, K - 1, di), (None, "batch", None, "ff"), jnp.bfloat16)
        out["conv_B"] = ((nl, B, K - 1, GN), (None, "batch", None, None), jnp.bfloat16)
        out["conv_C"] = ((nl, B, K - 1, GN), (None, "batch", None, None), jnp.bfloat16)
        out["ssm"] = ((nl, B, H, N, P), (None, "batch", "heads", None, None), jnp.float32)
    if cfg.family == "hybrid":
        napps = cfg.n_layers // cfg.shared_attn_every
        mode = kv_mode(cfg, topo)
        shp = (napps, B, S, cfg.n_kv_heads, cfg.head_dim)
        out["shared_k"] = (shp, _kv_axes(mode), jnp.bfloat16)
        out["shared_v"] = (shp, _kv_axes(mode), jnp.bfloat16)
    if cfg.family == "audio":
        mode = kv_mode(cfg, topo)
        shp = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
        xshp = (cfg.n_layers, B, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)
        out["k"] = (shp, _kv_axes(mode), jnp.bfloat16)
        out["v"] = (shp, _kv_axes(mode), jnp.bfloat16)
        out["xk"] = (xshp, (None, "batch", None, "kv_heads", None), jnp.bfloat16)
        out["xv"] = (xshp, (None, "batch", None, "kv_heads", None), jnp.bfloat16)
    return out


def cache_abstract(cfg, topo, B, S):
    return {k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, _, dt) in cache_specs(cfg, topo, B, S).items()}


def cache_shardings(cfg, topo, B, S):
    return {k: topo.sharding_for(shp, ax)
            for k, (shp, ax, dt) in cache_specs(cfg, topo, B, S).items()}


def init_cache(cfg, topo, B, S):
    return {k: jnp.zeros(shp, dt)
            for k, (shp, _, dt) in cache_specs(cfg, topo, B, S).items()}


# ---------------------------------------------------------------------------
# Hybrid decode attention
# ---------------------------------------------------------------------------
def _flash_decode_shardmap(cfg: ModelConfig, topo: Topology, q, kc, vc, lens,
                           window: Optional[int]):
    """The RPC path: q broadcast to sequence shards, partial (m,l,o) combined
    by psum — compute runs where the KV rows live."""
    B, S, Hkv, hd = kc.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = hd ** -0.5
    tp = topo.axis_sizes.get("model", 1)
    S_loc = S // tp

    q_spec = topo.spec_for(q.shape, ("batch", None, None))
    kv_spec = topo.spec_for(kc.shape, ("batch", "kv_seq", None, None))
    len_spec = topo.spec_for(lens.shape, ("batch",))

    def f(q_, kc_, vc_, lens_):
        r = lax.axis_index("model")
        pos = r * S_loc + jnp.arange(S_loc)
        mask = pos[None] < lens_[:, None]
        if window is not None:
            mask &= pos[None] > (lens_[:, None] - 1) - window
        qg = q_.reshape(B_loc(q_), Hkv, G, hd)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kc_,
                       preferred_element_type=jnp.float32) * scale
        s = L.softcap(s, cfg.attn_softcap)
        s = jnp.where(mask[:, None, None], s, -1e30)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", p.astype(vc_.dtype), vc_,
                       preferred_element_type=jnp.float32)
        # combine partials across shards (the RPC replies)
        Mg = lax.pmax(m, "model")
        corr = jnp.exp(m - Mg)
        Lg = lax.psum(l * corr, "model")
        Og = lax.psum(o * corr[..., None], "model")
        out = Og / jnp.maximum(Lg, 1e-30)[..., None]
        return out.reshape(q_.shape).astype(q_.dtype)

    def B_loc(q_):
        return q_.shape[0]

    return jax.shard_map(f, mesh=topo.mesh,
                         in_specs=(q_spec, kv_spec, kv_spec, len_spec),
                         out_specs=q_spec, check_vma=False)(q, kc, vc, lens)


def hybrid_decode_attention(cfg: ModelConfig, topo: Topology, q, kc, vc, lens,
                            *, window=None, mode: Optional[str] = None):
    """q: (B, Hq, hd); kc/vc: (B, S, Hkv, hd); lens: (B,)."""
    mode = mode or kv_mode(cfg, topo)
    if mode == "heads":
        # one-sided path: every head's K/V rows are local to its shard
        q = topo.constrain(q, "batch", "heads", None)
        return L.decode_attention(q, kc, vc, lens, window=window,
                                  attn_softcap=cfg.attn_softcap)
    return _flash_decode_shardmap(cfg, topo, q, kc, vc, lens, window)


def append_kv(kc, vc, k_new, v_new, lens):
    """One-sided WRITE of the new token's K/V at offset `len` (per row)."""
    B = lens.shape[0]
    rows = jnp.arange(B)
    kc = kc.at[rows, lens].set(k_new.astype(kc.dtype))
    vc = vc.at[rows, lens].set(v_new.astype(vc.dtype))
    return kc, vc


# ---------------------------------------------------------------------------
# Transformer decode
# ---------------------------------------------------------------------------
def _rope_single(x, lens, theta):
    """x: (B, H, hd) at per-row positions lens (B,)."""
    cos, sin = L.rope_tables(lens.astype(jnp.int32), x.shape[-1], theta)
    return L.apply_rope(x[:, None], cos[:, None], sin[:, None])[:, 0]


def _tf_decode_layer(cfg, topo, p, h, kc, vc, lens, *, local: bool):
    B, d = h.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    hn = L.rms_norm(h, p["attn_norm"])
    q = jnp.einsum("bd,dq->bq", hn, p["wq"])
    k = jnp.einsum("bd,dq->bq", hn, p["wk"])
    v = jnp.einsum("bd,dq->bq", hn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _rope_single(q.reshape(B, Hq, hd), lens, cfg.rope_theta)
    k = _rope_single(k.reshape(B, Hkv, hd), lens, cfg.rope_theta)
    v = v.reshape(B, Hkv, hd)
    kc, vc = append_kv(kc, vc, k, v, lens)
    window = cfg.sliding_window if local else None
    att = hybrid_decode_attention(cfg, topo, q, kc, vc, lens + 1, window=window)
    o = jnp.einsum("bq,qd->bd", att.reshape(B, Hq * hd), p["wo"])
    if cfg.post_norms:
        o = L.rms_norm(o, p["attn_post_norm"])
    h = h + o
    hn = L.rms_norm(h, p["mlp_norm"])
    if cfg.is_moe:
        out = moe_ffn(cfg, topo, hn[:, None], p["router"], p["we_gate"],
                      p["we_up"], p["we_down"])[:, 0]
        if cfg.n_shared_experts:
            out = out + L.swiglu(hn, p["ws_gate"], p["ws_up"], p["ws_down"])
    else:
        out = L.swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.post_norms:
        out = L.rms_norm(out, p["mlp_post_norm"])
    return h + out, kc, vc


def _tf_decode(cfg: ModelConfig, topo: Topology, params, cache, tokens):
    """tokens: (B,) int32.  Returns (logits (B, V), cache)."""
    B = tokens.shape[0]
    lens = cache["len"]
    h = embed_lookup(topo, params["embed"], tokens[:, None])[:, 0]
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    g = max(1, cfg.local_global_pattern)
    Lyr = cfg.n_layers
    stacked = jax.tree.map(
        lambda a: a.reshape((Lyr // g, g) + a.shape[1:]), params["layers"])
    kcs = cache["k"].reshape((Lyr // g, g) + cache["k"].shape[1:])
    vcs = cache["v"].reshape((Lyr // g, g) + cache["v"].shape[1:])

    def body(h, xs):
        gp, kc_g, vc_g = xs
        nk, nv = [], []
        for i in range(g):
            pk = jax.tree.map(lambda a: a[i], gp)
            local = (cfg.local_global_pattern == 2 and i == 0)
            h, kc, vc = _tf_decode_layer(cfg, topo, pk, h, kc_g[i], vc_g[i],
                                         lens, local=local)
            nk.append(kc)
            nv.append(vc)
        return h, (jnp.stack(nk), jnp.stack(nv))

    h, (nk, nv) = lax.scan(body, h, (stacked, kcs, vcs))
    cache = dict(cache)
    cache["k"] = nk.reshape(cache["k"].shape)
    cache["v"] = nv.reshape(cache["v"].shape)
    cache["len"] = lens + 1
    h = L.rms_norm(h, params["final_norm"])
    table = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h, table,
                        preferred_element_type=jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


# ---------------------------------------------------------------------------
# Mamba / hybrid decode
# ---------------------------------------------------------------------------
def _ssm_decode_layer(cfg, topo, p, h, conv_x, conv_B, conv_C, ssm_st):
    h2, (ncs, nss) = M.mamba_block(
        cfg, topo, p, h[:, None], conv_state=(conv_x, conv_B, conv_C),
        ssm_state=ssm_st, decode=True)
    return h2[:, 0], ncs, nss


def _ssm_decode(cfg: ModelConfig, topo: Topology, params, cache, tokens):
    B = tokens.shape[0]
    h = embed_lookup(topo, params["embed"], tokens[:, None])[:, 0]

    def body(h, xs):
        lp, cx, cb, cc, st = xs
        h, (ncx, ncb, ncc), nst = _ssm_decode_layer(cfg, topo, lp, h, cx, cb, cc, st)
        return h, (ncx, ncb, ncc, nst)

    h, (ncx, ncb, ncc, nst) = lax.scan(
        body, h, (params["layers"], cache["conv_x"], cache["conv_B"],
                  cache["conv_C"], cache["ssm"]))
    cache = dict(cache, conv_x=ncx, conv_B=ncb, conv_C=ncc, ssm=nst,
                 len=cache["len"] + 1)
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


def _shared_decode_block(cfg, topo, p, h, kc, vc, lens):
    """Zamba shared transformer block, decode flavour (no pattern/moe)."""
    import dataclasses as dc
    scfg = dc.replace(cfg, d_ff=cfg.shared_d_ff, n_experts=0, qkv_bias=False,
                      post_norms=False)
    return _tf_decode_layer(scfg, topo, p, h, kc, vc, lens, local=False)


def _hybrid_decode(cfg: ModelConfig, topo: Topology, params, cache, tokens):
    B = tokens.shape[0]
    k = cfg.shared_attn_every
    n_scan = (cfg.n_layers // k) * k
    lens = cache["len"]
    h = embed_lookup(topo, params["embed"], tokens[:, None])[:, 0]
    shared = params["shared"]
    grp = jax.tree.map(
        lambda a: a.reshape((n_scan // k, k) + a.shape[1:]), params["layers"])
    sub = lambda t, n=n_scan // k, kk=k: jax.tree.map(
        lambda a: a.reshape((n, kk) + a.shape[1:]), t)

    def body(h, xs):
        gp, cx, cb, cc, st, skc, svc = xs
        ncx, ncb, ncc, nst = [], [], [], []
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], gp)
            h, (a, b, c), s = _ssm_decode_layer(
                cfg, topo, lp, h, cx[i], cb[i], cc[i], st[i])
            ncx.append(a); ncb.append(b); ncc.append(c); nst.append(s)
        h, skc, svc = _shared_decode_block(cfg, topo, shared, h, skc, svc, lens)
        return h, (jnp.stack(ncx), jnp.stack(ncb), jnp.stack(ncc),
                   jnp.stack(nst), skc, svc)

    xs = (grp, *[sub(cache[n][:n_scan]) for n in
                 ("conv_x", "conv_B", "conv_C", "ssm")],
          cache["shared_k"], cache["shared_v"])
    h, (ncx, ncb, ncc, nst, nskc, nsvc) = lax.scan(body, h, xs)

    cache = dict(cache)
    for name, new in (("conv_x", ncx), ("conv_B", ncb), ("conv_C", ncc),
                      ("ssm", nst)):
        flat = new.reshape((n_scan,) + new.shape[2:])
        if n_scan < cfg.n_layers:
            pass
        cache[name] = cache[name].at[:n_scan].set(flat.astype(cache[name].dtype))
    cache["shared_k"], cache["shared_v"] = nskc, nsvc

    if "tail_layers" in params:
        def tail(h, xs):
            lp, cx, cb, cc, st = xs
            h, (a, b, c), s = _ssm_decode_layer(cfg, topo, lp, h, cx, cb, cc, st)
            return h, (a, b, c, s)
        n_tail = cfg.n_layers - n_scan
        h, (tcx, tcb, tcc, tst) = lax.scan(
            tail, h, (params["tail_layers"],
                      *[cache[n][n_scan:] for n in
                        ("conv_x", "conv_B", "conv_C", "ssm")]))
        for name, new in (("conv_x", tcx), ("conv_B", tcb), ("conv_C", tcc),
                          ("ssm", tst)):
            cache[name] = cache[name].at[n_scan:].set(
                new.astype(cache[name].dtype))
    cache["len"] = lens + 1
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


# ---------------------------------------------------------------------------
# Whisper decode
# ---------------------------------------------------------------------------
def _wh_decode_layer(cfg, topo, p, h, kc, vc, xk, xv, lens):
    B, d = h.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    hn = L.layer_norm(h, p["s_ln_w"], p["s_ln_b"])
    q = (jnp.einsum("bd,dq->bq", hn, p["s_wq"]) + p["s_bq"]).reshape(B, Hq, hd)
    k = jnp.einsum("bd,dq->bq", hn, p["s_wk"]).reshape(B, Hkv, hd)
    v = (jnp.einsum("bd,dq->bq", hn, p["s_wv"]) + p["s_bv"]).reshape(B, Hkv, hd)
    kc, vc = append_kv(kc, vc, k, v, lens)
    att = hybrid_decode_attention(cfg, topo, q, kc, vc, lens + 1)
    h = h + jnp.einsum("bq,qd->bd", att.reshape(B, Hq * hd), p["s_wo"]) + p["s_bo"]
    # cross attention: READ-ONLY remote region (one-sided reads)
    hn = L.layer_norm(h, p["x_ln_w"], p["x_ln_b"])
    q = (jnp.einsum("bd,dq->bq", hn, p["x_wq"]) + p["x_bq"]).reshape(B, Hq, hd)
    xlen = jnp.full((B,), xk.shape[1], jnp.int32)
    att = hybrid_decode_attention(cfg, topo, q, xk, xv, xlen, mode="heads")
    h = h + jnp.einsum("bq,qd->bd", att.reshape(B, Hq * hd), p["x_wo"]) + p["x_bo"]
    hn = L.layer_norm(h, p["m_ln_w"], p["m_ln_b"])
    h = h + L.gelu_mlp(hn, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    return h, kc, vc


def _wh_decode(cfg: ModelConfig, topo: Topology, params, cache, tokens):
    B = tokens.shape[0]
    lens = cache["len"]
    from repro.models.whisper import sinusoid
    h = embed_lookup(topo, params["embed"], tokens[:, None])[:, 0]
    h = h + jnp.take(sinusoid(cache["k"].shape[2], cfg.d_model), lens, axis=0)

    def body(h, xs):
        lp, kc, vc, xk, xv = xs
        h, kc, vc = _wh_decode_layer(cfg, topo, lp, h, kc, vc, xk, xv, lens)
        return h, (kc, vc)

    h, (nk, nv) = lax.scan(body, h, (params["dec_layers"], cache["k"],
                                     cache["v"], cache["xk"], cache["xv"]))
    cache = dict(cache, k=nk, v=nv, len=lens + 1)
    h = L.layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def make_decode_step(cfg: ModelConfig, topo: Topology):
    if cfg.family in ("dense", "moe", "vlm"):
        fn = _tf_decode
    elif cfg.family == "ssm":
        fn = _ssm_decode
    elif cfg.family == "hybrid":
        fn = _hybrid_decode
    elif cfg.family == "audio":
        fn = _wh_decode
    else:
        raise ValueError(cfg.family)

    def decode_step(params, cache, tokens):
        return fn(cfg, topo, params, cache, tokens)

    return decode_step


def make_prefill(cfg: ModelConfig, topo: Topology, S: int,
                 opts: RunOptions = RunOptions()):
    """Returns prefill(params, batch) -> (last_logits (B, V), cache).

    Prefill reuses the training forward blocks but emits per-layer K/V into
    the cache region (transformers) or carries SSM states (mamba/zamba)."""
    from repro.serving.prefill import prefill_fn
    return partial(prefill_fn, cfg, topo, S, opts)
