"""Prefill: forward pass over the prompt that populates the KV cache.

Structurally the training forward with (a) per-layer K/V emitted into the
cache region (a bulk one-sided WRITE of each layer's rows), (b) LM head on
the last position only, (c) no remat.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.embedding import embed_lookup
from repro.models.transformer import RunOptions, ffn_block
from repro.parallel.sharding import Topology
from repro.serving.decode import kv_mode, _kv_axes


def _attn_with_cache(cfg, topo, p, h, cos, sin, *, window, opts):
    """Like transformer.attention_block but returns (h, k_cache_rows, v_...)."""
    B, S, d = h.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    tp = topo.axis_sizes.get("model", 1)
    hn = L.rms_norm(h, p["attn_norm"])
    q = jnp.einsum("bsd,dq->bsq", hn, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", hn, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", hn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(q.reshape(B, S, Hq, hd), cos, sin)
    k = L.apply_rope(k.reshape(B, S, Hkv, hd), cos, sin)
    v = v.reshape(B, S, Hkv, hd)
    kv_ax = _kv_axes(kv_mode(cfg, topo))[1:]
    k = topo.constrain(k, *kv_ax)
    v = topo.constrain(v, *kv_ax)

    head_tp = (tp == 1) or (Hq % tp == 0)
    if head_tp:
        ka, va = k, v
        if Hkv % max(tp, 1) != 0 and tp > 1:
            g = Hq // Hkv
            ka = jnp.repeat(k, g, axis=2)
            va = jnp.repeat(v, g, axis=2)
        q = topo.constrain(q, "batch", None, "heads", None)
        out = L.block_attention(q, ka, va, causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_block=opts.q_block, kv_block=opts.kv_block)
    else:
        q = topo.constrain(q, "batch", "kv_seq", None, None)
        out = L.block_attention(q, k, v, causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_block=S, kv_block=opts.kv_block)
        out = topo.constrain(out, "batch", "kv_seq", None, None)
    o = jnp.einsum("bsq,qd->bsd", out.reshape(B, S, Hq * hd), p["wo"])
    if cfg.post_norms:
        o = L.rms_norm(o, p["attn_post_norm"])
    return topo.constrain(h + o, "batch", None, None), k, v


def _tf_prefill(cfg: ModelConfig, topo: Topology, S, opts, params, batch):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    h = embed_lookup(topo, params["embed"], tokens)
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    if batch.get("patch_embeds") is not None:
        h = lax.dynamic_update_slice(
            h, batch["patch_embeds"].astype(h.dtype), (0, 0, 0))
    h = topo.constrain(h, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    g = max(1, cfg.local_global_pattern)
    Lyr = cfg.n_layers
    stacked = jax.tree.map(
        lambda a: a.reshape((Lyr // g, g) + a.shape[1:]), params["layers"])

    def body(h, gp):
        ks, vs = [], []
        for i in range(g):
            pk = jax.tree.map(lambda a: a[i], gp)
            local = (cfg.local_global_pattern == 2 and i == 0)
            h, k, v = _attn_with_cache(
                cfg, topo, pk, h, cos, sin,
                window=cfg.sliding_window if local else None, opts=opts)
            h = ffn_block(cfg, topo, pk, h)
            ks.append(k)
            vs.append(v)
        return h, (jnp.stack(ks), jnp.stack(vs))

    h, (ks, vs) = lax.scan(body, h, stacked)
    kc = ks.reshape((Lyr,) + ks.shape[2:])
    vc = vs.reshape((Lyr,) + vs.shape[2:])
    h = L.rms_norm(h[:, -1], params["final_norm"])
    table = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h, table,
                        preferred_element_type=jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    cache = {"k": kc, "v": vc, "len": jnp.full((B,), S, jnp.int32)}
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


def _ssm_prefill(cfg, topo, S, opts, params, batch):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    h = embed_lookup(topo, params["embed"], tokens)
    h = topo.constrain(h, "batch", None, None)
    zc = lambda shp, dt=jnp.bfloat16: jnp.zeros(shp, dt)
    K, di, GN = cfg.conv_width, cfg.d_inner, cfg.ssm_groups * cfg.ssm_state

    def body(h, lp):
        cs = (zc((B, K - 1, di)), zc((B, K - 1, GN)), zc((B, K - 1, GN)))
        h, (ncs, nst) = M.mamba_block(cfg, topo, lp, h, conv_state=cs,
                                      ssm_state=None)
        return h, (ncs[0], ncs[1], ncs[2], nst)

    h, (cx, cb, cc, st) = lax.scan(body, h, params["layers"])
    h = L.rms_norm(h[:, -1], params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": st,
             "len": jnp.full((B,), S, jnp.int32)}
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


def _hybrid_prefill(cfg, topo, S, opts, params, batch):
    from repro.models.zamba import _shared_cfg
    tokens = batch["tokens"]
    B = tokens.shape[0]
    k = cfg.shared_attn_every
    n_scan = (cfg.n_layers // k) * k
    h = embed_lookup(topo, params["embed"], tokens)
    h = topo.constrain(h, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    shared = params["shared"]
    scfg = _shared_cfg(cfg)
    zc = lambda shp, dt=jnp.bfloat16: jnp.zeros(shp, dt)
    K, di, GN = cfg.conv_width, cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
    grp = jax.tree.map(
        lambda a: a.reshape((n_scan // k, k) + a.shape[1:]), params["layers"])

    def body(h, gp):
        cxs, cbs, ccs, sts = [], [], [], []
        for i in range(k):
            lp = jax.tree.map(lambda a: a[i], gp)
            cs = (zc((B, K - 1, di)), zc((B, K - 1, GN)), zc((B, K - 1, GN)))
            h, (ncs, nst) = M.mamba_block(cfg, topo, lp, h, conv_state=cs,
                                          ssm_state=None)
            cxs.append(ncs[0]); cbs.append(ncs[1]); ccs.append(ncs[2])
            sts.append(nst)
        h, sk, sv = _attn_with_cache(scfg, topo, shared, h, cos, sin,
                                     window=None, opts=opts)
        h = ffn_block(scfg, topo, shared, h)
        return h, (jnp.stack(cxs), jnp.stack(cbs), jnp.stack(ccs),
                   jnp.stack(sts), sk, sv)

    h, (cx, cb, cc, st, sk, sv) = lax.scan(body, h, grp)
    reshp = lambda a: a.reshape((n_scan,) + a.shape[2:])
    cx, cb, cc, st = map(reshp, (cx, cb, cc, st))
    if "tail_layers" in params:
        def tail(h, lp):
            cs = (zc((B, K - 1, di)), zc((B, K - 1, GN)), zc((B, K - 1, GN)))
            h, (ncs, nst) = M.mamba_block(cfg, topo, lp, h, conv_state=cs,
                                          ssm_state=None)
            return h, (ncs[0], ncs[1], ncs[2], nst)
        h, (tx, tb, tc, ts) = lax.scan(tail, h, params["tail_layers"])
        cx = jnp.concatenate([cx, tx]); cb = jnp.concatenate([cb, tb])
        cc = jnp.concatenate([cc, tc]); st = jnp.concatenate([st, ts])
    h = L.rms_norm(h[:, -1], params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": st,
             "shared_k": sk, "shared_v": sv,
             "len": jnp.full((B,), S, jnp.int32)}
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


def _wh_prefill(cfg, topo, S, opts, params, batch):
    from repro.models.whisper import encode, sinusoid
    tokens = batch["tokens"]
    B = tokens.shape[0]
    frames = batch.get("frames")
    if frames is None:
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    enc_out = encode(cfg, topo, params, frames, opts)
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    h = embed_lookup(topo, params["embed"], tokens)
    h = h + sinusoid(S, cfg.d_model)[None]
    h = topo.constrain(h, "batch", None, None)

    def body(h, lp):
        # decoder self-attention with cache emission
        hn = L.layer_norm(h, lp["s_ln_w"], lp["s_ln_b"])
        q = (jnp.einsum("bsd,dq->bsq", hn, lp["s_wq"]) + lp["s_bq"]
             ).reshape(B, S, Hq, hd)
        k = jnp.einsum("bsd,dq->bsq", hn, lp["s_wk"]).reshape(B, S, Hkv, hd)
        v = (jnp.einsum("bsd,dq->bsq", hn, lp["s_wv"]) + lp["s_bv"]
             ).reshape(B, S, Hkv, hd)
        out = L.block_attention(q, k, v, causal=True, q_block=opts.q_block,
                                kv_block=opts.kv_block)
        h = h + jnp.einsum("bsq,qd->bsd", out.reshape(B, S, Hq * hd),
                           lp["s_wo"]) + lp["s_bo"]
        # cross attention + cross-cache emission
        hn = L.layer_norm(h, lp["x_ln_w"], lp["x_ln_b"])
        q = (jnp.einsum("bsd,dq->bsq", hn, lp["x_wq"]) + lp["x_bq"]
             ).reshape(B, S, Hq, hd)
        xk = jnp.einsum("bsd,dq->bsq", enc_out, lp["x_wk"]).reshape(
            B, cfg.encoder_seq, Hkv, hd)
        xv = (jnp.einsum("bsd,dq->bsq", enc_out, lp["x_wv"]) + lp["x_bv"]
              ).reshape(B, cfg.encoder_seq, Hkv, hd)
        out = L.block_attention(q, xk, xv, causal=False, q_block=opts.q_block,
                                kv_block=opts.kv_block)
        h = h + jnp.einsum("bsq,qd->bsd", out.reshape(B, S, Hq * hd),
                           lp["x_wo"]) + lp["x_bo"]
        hn = L.layer_norm(h, lp["m_ln_w"], lp["m_ln_b"])
        h = h + L.gelu_mlp(hn, lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"])
        return topo.constrain(h, "batch", None, None), (k, v, xk, xv)

    h, (kc, vc, xkc, xvc) = lax.scan(body, h, params["dec_layers"])
    h = L.layer_norm(h[:, -1], params["dec_ln_w"], params["dec_ln_b"])
    logits = jnp.einsum("bd,vd->bv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    cache = {"k": kc, "v": vc, "xk": xkc, "xv": xvc,
             "len": jnp.full((B,), S, jnp.int32)}
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", "vocab"), cache


def prefill_fn(cfg: ModelConfig, topo: Topology, S: int, opts: RunOptions,
               params, batch):
    if cfg.family in ("dense", "moe", "vlm"):
        return _tf_prefill(cfg, topo, S, opts, params, batch)
    if cfg.family == "ssm":
        return _ssm_prefill(cfg, topo, S, opts, params, batch)
    if cfg.family == "hybrid":
        return _hybrid_prefill(cfg, topo, S, opts, params, batch)
    if cfg.family == "audio":
        return _wh_prefill(cfg, topo, S, opts, params, batch)
    raise ValueError(cfg.family)
