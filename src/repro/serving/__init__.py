from repro.serving.decode import (cache_specs, init_cache, make_prefill,  # noqa: F401
                                  make_decode_step)
