"""train_step: forward (remat-scanned layers) + backward + AdamW, with
optional microbatched gradient accumulation.  Everything is a pure function
of (state, batch) so jit donation keeps buffers in place."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models.transformer import RunOptions
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state, opt_state_specs
from repro.parallel.sharding import Topology, init_params, is_spec
from repro.train.loss import lm_loss


@dataclasses.dataclass(frozen=True)
class TrainHparams:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    opts: RunOptions = RunOptions()


def make_train_state_specs(cfg: ModelConfig) -> Dict[str, Any]:
    pspecs = api.param_specs(cfg)
    return {"params": pspecs, "opt": opt_state_specs(pspecs)}


def init_train_state(cfg: ModelConfig, key):
    params = init_params(api.param_specs(cfg), key)
    return {"params": params, "opt": init_opt_state(params)}


def state_shardings(topo: Topology, specs):
    return jax.tree.map(lambda s: topo.sharding_for(s.shape, s.logical_axes),
                        specs, is_leaf=is_spec)


def make_train_step(cfg: ModelConfig, topo: Topology,
                    hp: TrainHparams = TrainHparams()):
    def loss_fn(params, batch):
        logits = api.forward(cfg, topo, params, batch, opts=hp.opts)
        labels = batch["labels"]
        loss, metrics = lm_loss(logits, labels, batch.get("mask"))
        return loss, metrics

    def train_step(state, batch):
        params = state["params"]
        if hp.microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((hp.microbatches, b // hp.microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, metric_acc = carry
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                metric_acc = jax.tree.map(jnp.add, metric_acc, metrics)
                return (g_acc, metric_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros(()), "accuracy": jnp.zeros(()),
                  "tokens": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / hp.microbatches, grads)
            metrics = jax.tree.map(lambda x: x / hp.microbatches, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        new_params, new_opt, opt_metrics = apply_updates(
            hp.optimizer, grads, state["opt"])
        metrics = {**metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, topo: Topology, opts: RunOptions = RunOptions()):
    def eval_step(params, batch):
        logits = api.forward(cfg, topo, params, batch, opts=opts)
        _, metrics = lm_loss(logits, batch["labels"], batch.get("mask"))
        return metrics
    return eval_step
