"""Vocab-sharded cross-entropy: the target-logit term is an iota-compare
contraction (never materializes one-hot), so both the logsumexp and the
gather reduce over the locally-held vocab shard + one scalar-ish psum."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits, labels, mask=None):
    """logits: (B, S, V) f32 (vocab-sharded); labels: (B, S) int32.
    Returns (loss, metrics)."""
    B, S, V = logits.shape
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (B, S, V), 2)
    tgt = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - tgt
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
