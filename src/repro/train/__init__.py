from repro.train.step import TrainHparams, make_train_step, make_train_state_specs, init_train_state  # noqa: F401
from repro.train.loss import lm_loss  # noqa: F401
