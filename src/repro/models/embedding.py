"""Vocab-sharded embedding lookup — a Storm hybrid integration point.

The embedding table is a remote data structure sharded over the `model` axis
(each shard owns a contiguous vocab range — Storm's contiguous region).  Two
access modes:

  * "rpc"  (default): ship the ids to every vocab shard; each shard computes
    the rows it owns (the handler) and a psum combines — compute-at-the-data.
    Wire cost per layer: one psum of (B_loc, S, d).
  * "onesided": all-gather the table shards to the requester and gather rows
    locally — data-to-compute.  Only wins for tiny tables (cost_model).

The LM head needs no shard_map: logits stay vocab-sharded under SPMD and the
loss reduces over the sharded axis in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cost_model
from repro.parallel.sharding import Topology


def embed_lookup(topo: Topology, table: jax.Array, tokens: jax.Array,
                 mode: str = "auto") -> jax.Array:
    """table: (V, d) sharded ("vocab"=model, None); tokens: (B, S) int32.
    Returns (B, S, d) batch-sharded, replicated over model."""
    V, d = table.shape
    tp = topo.axis_sizes.get("model", 1)
    vocab_axes = topo._mesh_axes_for("vocab", V)
    if tp == 1 or V % tp != 0 or not vocab_axes:
        return jnp.take(table, tokens, axis=0)

    if mode == "auto":
        toks_per_shard = int(jnp.size(tokens))  # global tokens
        choice = cost_model.embedding_lookup_choice(
            tokens_per_shard=toks_per_shard // max(topo.axis_sizes.get("data", 1), 1),
            d_model=d, vocab=V, shards=tp)
        mode = choice.mode

    batch_spec = topo.spec_for(tokens.shape, ("batch", None))
    table_spec = topo.spec_for(table.shape, ("vocab", None))
    out_spec = topo.spec_for(tokens.shape + (d,), ("batch", None, None))
    vs = V // tp

    if mode == "onesided":
        def one(tbl, toks):
            full = lax.all_gather(tbl, "model", axis=0, tiled=True)
            return jnp.take(full, toks.astype(jnp.int32), axis=0)
        fn = one
    else:
        def rpc(tbl, toks):
            m = lax.axis_index("model")
            ids = toks.astype(jnp.int32) - m * vs
            ok = (ids >= 0) & (ids < vs)
            rows = jnp.take(tbl, jnp.clip(ids, 0, vs - 1), axis=0)
            rows = jnp.where(ok[..., None], rows, jnp.zeros((), tbl.dtype))
            return lax.psum(rows, "model")
        fn = rpc

    return jax.shard_map(
        fn, mesh=topo.mesh, in_specs=(table_spec, batch_spec),
        out_specs=out_spec, check_vma=False)(table, tokens)
