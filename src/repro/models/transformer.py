"""Decoder-only LM assembly: dense / MoE / VLM backbones, gemma2-style
local-global alternation, GQA, qkv-bias, softcaps.

Layers are scanned in GROUPS (group = the local/global pattern period) so the
pair-scheduled attention keeps a STATIC schedule per sub-layer kind while HLO
stays O(1) in depth.  Remat wraps the group body.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.embedding import embed_lookup
from repro.models.moe import moe_ffn
from repro.parallel.sharding import ParamSpec as PS, Topology


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def layer_param_specs(cfg: ModelConfig, n_layers: Optional[int] = None,
                      stacked: bool = True):
    d, hd = cfg.d_model, cfg.head_dim
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    Ldim = (n_layers if n_layers is not None else cfg.n_layers,) if stacked else ()
    Lax = (None,) if stacked else ()
    p = {
        "attn_norm": PS(Ldim + (d,), Lax + (None,), "ones"),
        "wq": PS(Ldim + (d, qd), Lax + ("fsdp", "heads"), "scaled"),
        "wk": PS(Ldim + (d, kvd), Lax + ("fsdp", "kv_heads"), "scaled"),
        "wv": PS(Ldim + (d, kvd), Lax + ("fsdp", "kv_heads"), "scaled"),
        "wo": PS(Ldim + (qd, d), Lax + ("heads", "fsdp"), "scaled"),
        "mlp_norm": PS(Ldim + (d,), Lax + (None,), "ones"),
    }
    if cfg.qkv_bias:
        p["bq"] = PS(Ldim + (qd,), Lax + ("heads",), "zeros")
        p["bk"] = PS(Ldim + (kvd,), Lax + ("kv_heads",), "zeros")
        p["bv"] = PS(Ldim + (kvd,), Lax + ("kv_heads",), "zeros")
    if cfg.post_norms:
        p["attn_post_norm"] = PS(Ldim + (d,), Lax + (None,), "ones")
        p["mlp_post_norm"] = PS(Ldim + (d,), Lax + (None,), "ones")
    if cfg.is_moe:
        E, f = cfg.n_experts, cfg.d_ff
        p["router"] = PS(Ldim + (d, E), Lax + (None, None), "scaled")
        p["we_gate"] = PS(Ldim + (E, d, f), Lax + ("expert", "fsdp", None), "scaled")
        p["we_up"] = PS(Ldim + (E, d, f), Lax + ("expert", "fsdp", None), "scaled")
        p["we_down"] = PS(Ldim + (E, f, d), Lax + ("expert", None, "fsdp"), "scaled")
        if cfg.n_shared_experts:
            sf = cfg.n_shared_experts * f
            p["ws_gate"] = PS(Ldim + (d, sf), Lax + ("fsdp", "ff"), "scaled")
            p["ws_up"] = PS(Ldim + (d, sf), Lax + ("fsdp", "ff"), "scaled")
            p["ws_down"] = PS(Ldim + (sf, d), Lax + ("ff", "fsdp"), "scaled")
    else:
        f = cfg.d_ff
        p["w_gate"] = PS(Ldim + (d, f), Lax + ("fsdp", "ff"), "scaled")
        p["w_up"] = PS(Ldim + (d, f), Lax + ("fsdp", "ff"), "scaled")
        p["w_down"] = PS(Ldim + (f, d), Lax + ("ff", "fsdp"), "scaled")
    return p


def param_specs(cfg: ModelConfig):
    d = cfg.d_model
    tree = {
        "embed": PS((cfg.vocab_padded, d), ("vocab", None), "normal"),
        "final_norm": PS((d,), (None,), "ones"),
        "layers": layer_param_specs(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = PS((cfg.vocab_padded, d), ("vocab", None), "normal")
    return tree


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def attention_block(cfg: ModelConfig, topo: Topology, p, h, cos, sin, *,
                    window: Optional[int], q_block: int = 512,
                    kv_block: int = 512, pad_heads: bool = False):
    B, S, d = h.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    tp = topo.axis_sizes.get("model", 1)
    hn = L.rms_norm(h, p["attn_norm"])
    q = jnp.einsum("bsd,dq->bsq", hn, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", hn, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", hn, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    head_tp = (tp == 1) or (Hq % tp == 0)
    wo = p["wo"]
    H_out = Hq
    if head_tp:
        if Hkv % max(tp, 1) != 0 and tp > 1:
            # repeat KV so heads shard cleanly (granite kv=8, glm kv=2, ...)
            g = Hq // Hkv
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = topo.constrain(q, "batch", None, "heads", None)
        k = topo.constrain(k, "batch", None,
                           "heads" if k.shape[2] == Hq else "kv_heads", None)
        v = topo.constrain(v, "batch", None,
                           "heads" if v.shape[2] == Hq else "kv_heads", None)
        out = L.block_attention(q, k, v, causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_block=q_block, kv_block=kv_block)
    elif pad_heads:
        # §Perf A1: zero-pad heads to the next multiple of tp — EXACT math
        # (pad q/k/v heads are all-zero -> pad outputs are 0; wo gets zero
        # rows so nothing leaks), but heads now shard over `model`, killing
        # the seq-CP per-layer activation all-gathers (EXPERIMENTS.md §Perf).
        g = Hq // Hkv
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        Hpad = -(-Hq // tp) * tp
        padn = Hpad - Hq
        zpad = ((0, 0), (0, 0), (0, padn), (0, 0))
        q = jnp.pad(q, zpad)
        k = jnp.pad(k, zpad)
        v = jnp.pad(v, zpad)
        q = topo.constrain(q, "batch", None, "heads", None)
        k = topo.constrain(k, "batch", None, "heads", None)
        v = topo.constrain(v, "batch", None, "heads", None)
        out = L.block_attention(q, k, v, causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_block=q_block, kv_block=kv_block)
        wo = jnp.pad(wo, ((0, padn * hd), (0, 0)))
        H_out = Hpad
    else:
        # sequence-parallel attention: q sharded over model on seq; one q
        # block so q is never sliced (DESIGN §5 — qwen 40H/20H fallback)
        q = topo.constrain(q, "batch", "kv_seq", None, None)
        out = L.block_attention(q, k, v, causal=True, window=window,
                                attn_softcap=cfg.attn_softcap,
                                q_block=S, kv_block=kv_block)
        out = topo.constrain(out, "batch", "kv_seq", None, None)
    o = jnp.einsum("bsq,qd->bsd", out.reshape(B, S, H_out * hd), wo)
    if cfg.post_norms:
        o = L.rms_norm(o, p["attn_post_norm"])
    return topo.constrain(h + o, "batch", None, None)


def ffn_block(cfg: ModelConfig, topo: Topology, p, h, moe_mode: str = "auto"):
    hn = L.rms_norm(h, p["mlp_norm"])
    if cfg.is_moe:
        out = moe_ffn(cfg, topo, hn, p["router"], p["we_gate"], p["we_up"],
                      p["we_down"], mode=moe_mode)
        if cfg.n_shared_experts:
            out = out + L.swiglu(hn, p["ws_gate"], p["ws_up"], p["ws_down"])
    else:
        out = L.swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.post_norms:
        out = L.rms_norm(out, p["mlp_post_norm"])
    return topo.constrain(h + out, "batch", None, None)


def decoder_layer(cfg: ModelConfig, topo: Topology, p, h, cos, sin, *,
                  local: bool, q_block: int = 512, kv_block: int = 512,
                  pad_heads: bool = False, moe_mode: str = "auto"):
    window = cfg.sliding_window if local else None
    h = attention_block(cfg, topo, p, h, cos, sin, window=window,
                        q_block=q_block, kv_block=kv_block,
                        pad_heads=pad_heads)
    return ffn_block(cfg, topo, p, h, moe_mode=moe_mode)


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunOptions:
    q_block: int = 512
    kv_block: int = 512
    remat: bool = True
    remat_policy: Optional[str] = "dots"   # None | "dots" | "full"
    # §Perf knobs (EXPERIMENTS.md) — all EXACT-equivalent transforms:
    pad_heads: bool = False    # zero-pad q heads to shard over model (A1)
    moe_mode: str = "auto"     # force "rpc"/"onesided" for ablation (B1)


def _maybe_remat(fn, opts: RunOptions):
    if not opts.remat:
        return fn
    policy = None
    if opts.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def forward(cfg: ModelConfig, topo: Topology, params, tokens, *,
            extra_embeds=None, opts: RunOptions = RunOptions()):
    """tokens: (B, S) int32 -> logits (B, S, V) vocab-sharded."""
    B, S = tokens.shape
    d = cfg.d_model
    h = embed_lookup(topo, params["embed"], tokens)
    if cfg.embed_scale:
        h = h * jnp.asarray(np.sqrt(d), h.dtype)
    if extra_embeds is not None:
        # VLM stub: precomputed patch embeddings occupy the first P positions
        h = lax.dynamic_update_slice(h, extra_embeds.astype(h.dtype), (0, 0, 0))
    h = topo.constrain(h, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)

    g = max(1, cfg.local_global_pattern)
    Lyr = cfg.n_layers
    assert Lyr % g == 0, (Lyr, g)
    stacked = jax.tree.map(
        lambda a: a.reshape((Lyr // g, g) + a.shape[1:]), params["layers"])

    def group_body(carry, gp):
        hh = carry
        for kk in range(g):
            pk = jax.tree.map(lambda a: a[kk], gp)
            local = (cfg.local_global_pattern == 2 and kk == 0)
            hh = decoder_layer(cfg, topo, pk, hh, cos, sin, local=local,
                               q_block=opts.q_block, kv_block=opts.kv_block,
                               pad_heads=opts.pad_heads,
                               moe_mode=opts.moe_mode)
        return hh, None

    h, _ = lax.scan(_maybe_remat(group_body, opts), h, stacked)
    h = L.rms_norm(h, params["final_norm"])
    table = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", h, table,
                        preferred_element_type=jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", None, "vocab")
