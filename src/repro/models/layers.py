"""Core neural layers, built for clean SPMD partitioning and small HLO.

The training/prefill attention is a *pair-scheduled* blockwise flash
attention: the (q_block, kv_block) pairs that are actually needed under the
causal/sliding-window mask are enumerated at trace time (numpy) and processed
by ONE lax.scan — so HLO size is O(1) in sequence length and masked-out
blocks are never computed (no 2x causal waste).  The Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same schedule on-chip;
this jnp version is its oracle and the dry-run lowering path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_tables(positions, head_dim: int, theta: float):
    """positions: (..., S) int32 -> (cos, sin): (..., S, head_dim//2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def mask_pad_logits(logits, vocab_real: int):
    """Mask the padded vocab tail (see ModelConfig.vocab_padded)."""
    V = logits.shape[-1]
    if V == vocab_real:
        return logits
    iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < vocab_real, logits, jnp.asarray(-1e30, logits.dtype))


# ---------------------------------------------------------------------------
# Pair-scheduled blockwise attention
# ---------------------------------------------------------------------------
def _pair_schedule(n_q: int, n_k: int, q_block: int, kv_block: int,
                   causal: bool, window: Optional[int], q_offset: int):
    """Static (trace-time) list of (q_idx, k_idx) block pairs that intersect
    the mask.  q positions are q_offset + [0, n_q*q_block)."""
    pairs = []
    for i in range(n_q):
        q_lo = q_offset + i * q_block
        q_hi = q_offset + (i + 1) * q_block - 1
        for j in range(n_k):
            k_lo = j * kv_block
            k_hi = (j + 1) * kv_block - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi < q_lo - (window - 1):
                continue
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


def block_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    attn_softcap: Optional[float] = None,
                    q_block: int = 512, kv_block: int = 512,
                    q_offset: int = 0, scale: Optional[float] = None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) with Hq % Hkv == 0.

    GQA is handled by grouped einsums (no KV repetition).  Returns
    (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    pad_q = (-Sq) % qb
    pad_k = (-Sk) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    n_q, n_k = Sq_p // qb, Sk_p // kb

    qg = q.reshape(B, Sq_p, Hkv, G, D)
    pairs = _pair_schedule(n_q, n_k, qb, kb, causal, window, q_offset)

    NEG = jnp.float32(-1e30)
    acc0 = jnp.zeros((B, Hkv, G, Sq_p, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq_p), NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq_p), jnp.float32)

    # With a single q block (the sequence-parallel path where q stays sharded
    # over the model axis) q is never dynamically sliced — a dynamic_slice on
    # a sharded dim would force an all-gather.
    slice_q = n_q > 1

    def step(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qs = (lax.dynamic_slice_in_dim(qg, i * qb, qb, axis=1)
              if slice_q else qg)                                    # B,qb,Hkv,G,D
        ks = lax.dynamic_slice_in_dim(k, j * kb, kb, axis=1)         # B,kb,Hkv,D
        vs = lax.dynamic_slice_in_dim(v, j * kb, kb, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qs, ks,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, attn_softcap)
        qpos = (q_offset + i * qb + jnp.arange(qb)) if slice_q \
            else (q_offset + jnp.arange(qb))
        kpos = j * kb + jnp.arange(kb)
        mask = jnp.ones((qb, kb), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        if pad_k:
            mask &= (kpos[None, :] < Sk)
        s = jnp.where(mask[None, None, None], s, NEG)

        if slice_q:
            m_blk = lax.dynamic_slice_in_dim(m, i * qb, qb, axis=3)
            l_blk = lax.dynamic_slice_in_dim(l, i * qb, qb, axis=3)
            a_blk = lax.dynamic_slice_in_dim(acc, i * qb, qb, axis=3)
        else:
            m_blk, l_blk, a_blk = m, l, acc
        m_new = jnp.maximum(m_blk, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_blk - m_new)
        l_new = corr * l_blk + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), vs,
                        preferred_element_type=jnp.float32)
        a_new = corr[..., None] * a_blk + pv
        if slice_q:
            acc = lax.dynamic_update_slice_in_dim(acc, a_new, i * qb, axis=3)
            m = lax.dynamic_update_slice_in_dim(m, m_new, i * qb, axis=3)
            l = lax.dynamic_update_slice_in_dim(l, l_new, i * qb, axis=3)
        else:
            acc, m, l = a_new, m_new, l_new
        return (acc, m, l), None

    (acc, m, l), _ = lax.scan(step, (acc0, m0, l0), jnp.asarray(pairs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq_p, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=None, attn_softcap=None,
                  q_offset: int = 0, scale=None):
    """O(S^2)-materializing oracle for tests."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_softcap)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     attn_softcap=None, scale=None):
    """Single-token decode over a (B, S, Hkv, D) cache.  q: (B, Hq, D).
    cache_len: (B,) int32 — number of valid cache positions (the new token's
    K/V must already be appended).  Pure-jnp; the sequence-sharded "RPC path"
    wraps this per shard (serving.decode)."""
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, attn_softcap)
    pos = jnp.arange(S)
    mask = pos[None] < cache_len[:, None]
    if window is not None:
        mask &= pos[None] > (cache_len[:, None] - 1) - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jnp.einsum("...d,df->...f", x, w_in) + b_in
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out
