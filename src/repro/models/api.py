"""Family dispatcher: one entry point per model-zoo family."""
from __future__ import annotations

from typing import Any, Dict


from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, whisper, zamba
from repro.parallel.sharding import Topology


def param_specs(cfg: ModelConfig):
    if cfg.family == "ssm":
        return mamba2.param_specs(cfg)
    if cfg.family == "hybrid":
        return zamba.param_specs(cfg)
    if cfg.family == "audio":
        return whisper.param_specs(cfg)
    return transformer.param_specs(cfg)   # dense | moe | vlm


def forward(cfg: ModelConfig, topo: Topology, params, batch: Dict[str, Any], *,
            opts=None):
    """batch: {"tokens": (B,S) int32, optional "frames"/"patch_embeds"}.
    Returns logits (B, S, V) vocab-sharded."""
    tokens = batch["tokens"]
    if cfg.family == "ssm":
        return mamba2.forward(cfg, topo, params, tokens, opts=opts)
    if cfg.family == "hybrid":
        return zamba.forward(cfg, topo, params, tokens, opts=opts)
    if cfg.family == "audio":
        return whisper.forward(cfg, topo, params, tokens,
                               frames=batch.get("frames"), opts=opts)
    return transformer.forward(cfg, topo, params, tokens,
                               extra_embeds=batch.get("patch_embeds"),
                               opts=opts)
