"""Zamba2-style hybrid: Mamba2 backbone + ONE shared transformer block
(attention + MLP, a single weight copy) applied after every
`shared_attn_every`-th mamba layer (arXiv:2411.15242).

The shared block is the Storm "cache the hot data structure" analogue: one
replicated-parameter structure serving many call sites; its KV cache is the
remote region the serving layer shards (DESIGN §6).

Deviation noted in DESIGN.md: the original concatenates the residual stream
with the initial embedding at shared-block inputs and applies per-invocation
LoRA deltas; we apply the shared block directly on the stream (same comm and
compute pattern, fewer bells).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.embedding import embed_lookup
from repro.parallel.sharding import ParamSpec as PS, Topology


def _shared_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, d_ff=cfg.shared_d_ff, n_experts=0,
                               local_global_pattern=0, qkv_bias=False,
                               post_norms=False)


def param_specs(cfg: ModelConfig):
    from repro.models.transformer import layer_param_specs
    n_scan = (cfg.n_layers // cfg.shared_attn_every) * cfg.shared_attn_every
    n_tail = cfg.n_layers - n_scan
    tree = {
        "embed": PS((cfg.vocab_padded, cfg.d_model), ("vocab", None), "normal"),
        "final_norm": PS((cfg.d_model,), (None,), "ones"),
        "layers": M.mamba_layer_specs(cfg, n_layers=n_scan),
        "shared": layer_param_specs(_shared_cfg(cfg), stacked=False),
    }
    if n_tail:
        tree["tail_layers"] = M.mamba_layer_specs(cfg, n_layers=n_tail)
    return tree


def shared_block(cfg: ModelConfig, topo: Topology, p, h, cos, sin, opts):
    from repro.models.transformer import decoder_layer
    return decoder_layer(_shared_cfg(cfg), topo, p, h, cos, sin, local=False,
                         q_block=opts.q_block, kv_block=opts.kv_block)


def forward(cfg: ModelConfig, topo: Topology, params, tokens, *, opts=None):
    from repro.models.transformer import RunOptions, _maybe_remat
    opts = opts or RunOptions()
    B, S = tokens.shape
    k = cfg.shared_attn_every
    n_scan = (cfg.n_layers // k) * k
    h = embed_lookup(topo, params["embed"], tokens)
    h = topo.constrain(h, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cos, sin = L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    shared = params["shared"]

    stacked = jax.tree.map(
        lambda a: a.reshape((n_scan // k, k) + a.shape[1:]), params["layers"])

    def group(hh, gp):
        for i in range(k):
            pk = jax.tree.map(lambda a: a[i], gp)
            hh, _ = M.mamba_block(cfg, topo, pk, hh)
        hh = shared_block(cfg, topo, shared, hh, cos, sin, opts)
        return hh, None

    h, _ = lax.scan(_maybe_remat(group, opts), h, stacked)
    if "tail_layers" in params:
        def tail(hh, lp):
            hh, _ = M.mamba_block(cfg, topo, lp, hh)
            return hh, None
        h, _ = lax.scan(_maybe_remat(tail, opts), h, params["tail_layers"])
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", None, "vocab")
