"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, 1500, d).  The transformer backbone
is real: 24 encoder layers (bidirectional self-attention), 24 decoder layers
(causal self-attention + cross-attention), LayerNorm + GELU MLPs + biases.

For the Storm integration, the encoder output's K/V is the canonical
READ-ONLY remote region: once prefilled, every decode step issues one-sided
reads against it (no writer, no versions — the fast path of §4.4).

Deviation (DESIGN.md): sinusoidal decoder positions instead of Whisper's
learned 448-position table, so the assigned 4k/32k shapes are well-defined.
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.embedding import embed_lookup
from repro.parallel.sharding import ParamSpec as PS, Topology


def _attn_specs(cfg, Ldim, Lax, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    pre = "x" if cross else "s"
    return {
        f"{pre}_ln_w": PS(Ldim + (d,), Lax + (None,), "ones"),
        f"{pre}_ln_b": PS(Ldim + (d,), Lax + (None,), "zeros"),
        f"{pre}_wq": PS(Ldim + (d, qd), Lax + ("fsdp", "heads"), "scaled"),
        f"{pre}_bq": PS(Ldim + (qd,), Lax + ("heads",), "zeros"),
        f"{pre}_wk": PS(Ldim + (d, kvd), Lax + ("fsdp", "kv_heads"), "scaled"),
        f"{pre}_wv": PS(Ldim + (d, kvd), Lax + ("fsdp", "kv_heads"), "scaled"),
        f"{pre}_bv": PS(Ldim + (kvd,), Lax + ("kv_heads",), "zeros"),
        f"{pre}_wo": PS(Ldim + (qd, d), Lax + ("heads", "fsdp"), "scaled"),
        f"{pre}_bo": PS(Ldim + (d,), Lax + (None,), "zeros"),
    }


def _mlp_specs(cfg, Ldim, Lax):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "m_ln_w": PS(Ldim + (d,), Lax + (None,), "ones"),
        "m_ln_b": PS(Ldim + (d,), Lax + (None,), "zeros"),
        "w_in": PS(Ldim + (d, f), Lax + ("fsdp", "ff"), "scaled"),
        "b_in": PS(Ldim + (f,), Lax + ("ff",), "zeros"),
        "w_out": PS(Ldim + (f, d), Lax + ("ff", "fsdp"), "scaled"),
        "b_out": PS(Ldim + (d,), Lax + (None,), "zeros"),
    }


def param_specs(cfg: ModelConfig):
    d = cfg.d_model
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    enc = {**_attn_specs(cfg, (Le,), (None,)), **_mlp_specs(cfg, (Le,), (None,))}
    dec = {**_attn_specs(cfg, (Ld,), (None,)),
           **_attn_specs(cfg, (Ld,), (None,), cross=True),
           **_mlp_specs(cfg, (Ld,), (None,))}
    return {
        "embed": PS((cfg.vocab_padded, d), ("vocab", None), "normal"),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_ln_w": PS((d,), (None,), "ones"),
        "enc_ln_b": PS((d,), (None,), "zeros"),
        "dec_ln_w": PS((d,), (None,), "ones"),
        "dec_ln_b": PS((d,), (None,), "zeros"),
    }


def sinusoid(S: int, d: int):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.bfloat16)


def _mha(cfg, topo, h_q, h_kv, p, pre, *, causal, opts):
    B, Sq, d = h_q.shape
    hd, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (jnp.einsum("bsd,dq->bsq", h_q, p[f"{pre}_wq"]) + p[f"{pre}_bq"]
         ).reshape(B, Sq, Hq, hd)
    k = jnp.einsum("bsd,dq->bsq", h_kv, p[f"{pre}_wk"]).reshape(
        B, h_kv.shape[1], Hkv, hd)
    v = (jnp.einsum("bsd,dq->bsq", h_kv, p[f"{pre}_wv"]) + p[f"{pre}_bv"]
         ).reshape(B, h_kv.shape[1], Hkv, hd)
    q = topo.constrain(q, "batch", None, "heads", None)
    k = topo.constrain(k, "batch", None, "kv_heads", None)
    v = topo.constrain(v, "batch", None, "kv_heads", None)
    out = L.block_attention(q, k, v, causal=causal, q_block=opts.q_block,
                            kv_block=opts.kv_block)
    return jnp.einsum("bsq,qd->bsd", out.reshape(B, Sq, Hq * hd),
                      p[f"{pre}_wo"]) + p[f"{pre}_bo"]


def encoder_layer(cfg, topo, p, h, opts):
    hn = L.layer_norm(h, p["s_ln_w"], p["s_ln_b"])
    h = h + _mha(cfg, topo, hn, hn, p, "s", causal=False, opts=opts)
    hn = L.layer_norm(h, p["m_ln_w"], p["m_ln_b"])
    h = h + L.gelu_mlp(hn, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    return topo.constrain(h, "batch", None, None)


def decoder_layer(cfg, topo, p, h, enc_out, opts):
    hn = L.layer_norm(h, p["s_ln_w"], p["s_ln_b"])
    h = h + _mha(cfg, topo, hn, hn, p, "s", causal=True, opts=opts)
    hn = L.layer_norm(h, p["x_ln_w"], p["x_ln_b"])
    h = h + _mha(cfg, topo, hn, enc_out, p, "x", causal=False, opts=opts)
    hn = L.layer_norm(h, p["m_ln_w"], p["m_ln_b"])
    h = h + L.gelu_mlp(hn, p["w_in"], p["b_in"], p["w_out"], p["b_out"])
    return topo.constrain(h, "batch", None, None)


def encode(cfg, topo, params, frames, opts):
    """frames: (B, encoder_seq, d) — the precomputed conv-frontend output."""
    h = frames + sinusoid(frames.shape[1], cfg.d_model)[None]
    h = topo.constrain(h.astype(jnp.bfloat16), "batch", None, None)

    from repro.models.transformer import _maybe_remat

    def body(hh, lp):
        return encoder_layer(cfg, topo, lp, hh, opts), None

    h, _ = lax.scan(_maybe_remat(body, opts), h, params["enc_layers"])
    return L.layer_norm(h, params["enc_ln_w"], params["enc_ln_b"])


def forward(cfg: ModelConfig, topo: Topology, params, tokens, *,
            frames=None, opts=None):
    """Teacher-forced train/prefill: encode frames, decode tokens."""
    from repro.models.transformer import RunOptions, _maybe_remat
    opts = opts or RunOptions()
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    enc_out = encode(cfg, topo, params, frames, opts)
    h = embed_lookup(topo, params["embed"], tokens)
    h = h + sinusoid(S, cfg.d_model)[None]
    h = topo.constrain(h, "batch", None, None)

    def body(hh, lp):
        return decoder_layer(cfg, topo, lp, hh, enc_out, opts), None

    h, _ = lax.scan(_maybe_remat(body, opts), h, params["dec_layers"])
    h = L.layer_norm(h, params["dec_ln_w"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", None, "vocab")
