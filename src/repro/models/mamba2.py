"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Training/prefill uses the chunked SSD algorithm: ONE lax.scan over sequence
chunks carrying the inter-chunk state (B, H, N, P); each step computes the
intra-chunk quadratic term + the off-diagonal (state) term.  Decode is the
O(1) recurrent step.  Heads shard over `model` (48/16, 64/16 both divide);
B/C groups (G=1) replicate — every SSD einsum is head-local, so the layer
needs NO collectives beyond the in/out projections' FSDP gathers.

The Pallas kernel `repro.kernels.ssd_scan` implements the chunk step
on-chip; this jnp version is its oracle and the dry-run lowering path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import ParamSpec as PS, Topology


# ---------------------------------------------------------------------------
# Parameter specs (per layer, stackable)
# ---------------------------------------------------------------------------
def mamba_layer_specs(cfg: ModelConfig, n_layers: Optional[int] = None,
                      stacked: bool = True):
    d, di = cfg.d_model, cfg.d_inner
    H, P, N, G, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.ssm_groups, cfg.conv_width)
    Ldim = (n_layers if n_layers is not None else cfg.n_layers,) if stacked else ()
    Lax = (None,) if stacked else ()
    return {
        "norm": PS(Ldim + (d,), Lax + (None,), "ones"),
        "wz": PS(Ldim + (d, di), Lax + ("fsdp", "ff"), "scaled"),
        "wx": PS(Ldim + (d, di), Lax + ("fsdp", "ff"), "scaled"),
        "wB": PS(Ldim + (d, G * N), Lax + ("fsdp", None), "scaled"),
        "wC": PS(Ldim + (d, G * N), Lax + ("fsdp", None), "scaled"),
        "wdt": PS(Ldim + (d, H), Lax + ("fsdp", "heads"), "scaled"),
        "conv_x_w": PS(Ldim + (K, di), Lax + (None, "ff"), "normal", scale=0.1),
        "conv_x_b": PS(Ldim + (di,), Lax + ("ff",), "zeros"),
        "conv_B_w": PS(Ldim + (K, G * N), Lax + (None, None), "normal", scale=0.1),
        "conv_B_b": PS(Ldim + (G * N,), Lax + (None,), "zeros"),
        "conv_C_w": PS(Ldim + (K, G * N), Lax + (None, None), "normal", scale=0.1),
        "conv_C_b": PS(Ldim + (G * N,), Lax + (None,), "zeros"),
        "A_log": PS(Ldim + (H,), Lax + ("heads",), "zeros"),
        "D": PS(Ldim + (H,), Lax + ("heads",), "ones"),
        "dt_bias": PS(Ldim + (H,), Lax + ("heads",), "zeros"),
        "gnorm": PS(Ldim + (di,), Lax + ("ff",), "ones"),
        "wo": PS(Ldim + (di, d), Lax + ("ff", "fsdp"), "scaled"),
    }


def param_specs(cfg: ModelConfig):
    return {
        "embed": PS((cfg.vocab_padded, cfg.d_model), ("vocab", None), "normal"),
        "final_norm": PS((cfg.d_model,), (None,), "ones"),
        "layers": mamba_layer_specs(cfg),
    }


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------
def causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x: (B, S, C); w: (K, C); state: (B, K-1, C)
    carries the last K-1 inputs for decode continuity.
    Returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros((B, S, C), jnp.float32)
    for j in range(K):
        y = y + xp[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, S:]
    return y, new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P) bf16; dt: (B, S, H) f32 (post-softplus);
    A: (H,) f32 negative; Bm/Cm: (B, S, N) f32/bf16 (G=1 groups).
    Returns (y (B, S, H, P), final_state (B, H, N, P) f32).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    dA = dt * A  # (B, S, H), negative
    xdt = (xh.astype(jnp.float32) * dt[..., None])

    def to_chunks(t):
        return jnp.moveaxis(t.reshape((B, nc, Q) + t.shape[2:]), 1, 0)

    xs = (to_chunks(xdt), to_chunks(dA), to_chunks(Bm.astype(jnp.float32)),
          to_chunks(Cm.astype(jnp.float32)))
    S0 = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def step(Sprev, inp):
        xc, dAc, Bc, Cc = inp          # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(dAc, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: scores[q,k] = C_q.B_k * exp(cum_q - cum_k), k <= q.
        # Mask the EXPONENT (not the exp output): exp of the huge positive
        # delta in masked cells would be inf and poison the gradient.
        CB = jnp.einsum("bqn,bkn->bqk", Cc, Bc)                    # (B,Q,Q)
        delta = cum[:, :, None, :] - cum[:, None, :, :]            # (B,Q,Q,H)
        delta = jnp.where(causal[None, :, :, None], delta, -1e30)
        scores = CB[..., None] * jnp.exp(delta)
        y = jnp.einsum("bqkh,bkhp->bqhp", scores, xc)
        # off-diagonal: carry-in state
        y = y + jnp.einsum("bqn,bhnp->bqhp", Cc, Sprev) * jnp.exp(cum)[..., None]
        # next state
        dec_end = jnp.exp(cum[:, -1:, :] - cum)                     # (B,Q,H)
        Snew = (jnp.exp(cum[:, -1])[..., None, None] * Sprev
                + jnp.einsum("bkn,bkhp->bhnp", Bc, xc * dec_end[..., None]))
        return Snew, y

    Sfin, ys = lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y.astype(xh.dtype), Sfin


def ssd_ref(xh, dt, A, Bm, Cm):
    """O(S^2) oracle: full materialized decay matrix."""
    B, S, H, P = xh.shape
    dA = dt * A
    cum = jnp.cumsum(dA, axis=1)                                   # (B,S,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    delta = cum[:, :, None, :] - cum[:, None, :, :]                # (B,S,S,H)
    delta = jnp.where(causal[None, :, :, None], delta, -1e30)
    CB = jnp.einsum("bqn,bkn->bqk", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))
    scores = CB[..., None] * jnp.exp(delta)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y = jnp.einsum("bqkh,bkhp->bqhp", scores, xdt)
    return y.astype(xh.dtype)


def ssd_step(state, x1, dt1, A, B1, C1):
    """One decode step.  state: (B,H,N,P) f32; x1: (B,H,P); dt1: (B,H);
    B1/C1: (B,N).  Returns (new_state, y (B,H,P))."""
    dA = jnp.exp(dt1 * A)                                           # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", B1.astype(jnp.float32),
                     x1.astype(jnp.float32) * dt1[..., None])
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C1.astype(jnp.float32), state)
    return state, y.astype(x1.dtype)


# ---------------------------------------------------------------------------
# Layer + model
# ---------------------------------------------------------------------------
def mamba_block(cfg: ModelConfig, topo: Topology, p, h, *, conv_state=None,
                ssm_state=None, decode: bool = False):
    """h: (B, S, d).  In decode mode S == 1 and states are carried."""
    B, S, d = h.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    hn = L.rms_norm(h, p["norm"])
    z = jnp.einsum("bsd,de->bse", hn, p["wz"])
    xr = jnp.einsum("bsd,de->bse", hn, p["wx"])
    Br = jnp.einsum("bsd,dn->bsn", hn, p["wB"])
    Cr = jnp.einsum("bsd,dn->bsn", hn, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", hn, p["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    cs_x = cs_B = cs_C = None
    if conv_state is not None:
        cs_x, cs_B, cs_C = conv_state
    xc, ns_x = causal_conv(xr, p["conv_x_w"], p["conv_x_b"], cs_x)
    Bc, ns_B = causal_conv(Br, p["conv_B_w"], p["conv_B_b"], cs_B)
    Cc, ns_C = causal_conv(Cr, p["conv_C_w"], p["conv_C_b"], cs_C)
    new_conv_state = (ns_x, ns_B, ns_C)

    xh = xc.reshape(B, S, H, P)
    xh = topo.constrain(xh, "batch", None, "heads", None)
    if decode:
        assert S == 1
        st = (jnp.zeros((B, H, N, P), jnp.float32) if ssm_state is None
              else ssm_state)
        new_state, y1 = ssd_step(st, xh[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0])
        y = y1[:, None]
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk,
                                   init_state=ssm_state)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[:, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rms_norm(y, p["gnorm"])
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    h = topo.constrain(h + out, "batch", None, None)
    if decode or conv_state is not None or ssm_state is not None:
        return h, (new_conv_state, new_state)
    return h, None


def forward(cfg: ModelConfig, topo: Topology, params, tokens, *,
            opts=None):
    """Train/prefill forward -> logits (B, S, V)."""
    from repro.models.embedding import embed_lookup
    from repro.models.transformer import RunOptions, _maybe_remat
    opts = opts or RunOptions()
    B, S = tokens.shape
    h = embed_lookup(topo, params["embed"], tokens)
    h = topo.constrain(h, "batch", None, None)

    def body(carry, lp):
        hh, _ = carry
        hh, _st = mamba_block(cfg, topo, lp, hh)
        return (hh, 0), None

    (h, _), _ = lax.scan(_maybe_remat(body, opts), (h, 0), params["layers"])
    h = L.rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    logits = L.mask_pad_logits(logits, cfg.vocab_size)
    return topo.constrain(logits, "batch", None, "vocab")
