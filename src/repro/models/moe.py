"""Mixture-of-Experts with Storm's one-two-sided dispatch (DESIGN §3.2).

Experts are a remote data structure sharded over the `model` axis.  Per
(config, shape) the cost model picks the access mode at trace time:

  * "rpc":      compute-at-the-data.  Every model rank holds the full token
    set of its data shard (activations are TP-replicated); it runs ONLY its
    local experts over the tokens routed to them, and a psum("model")
    combines partial outputs.  Wire: one psum of (B_loc,S,d) — exactly the
    all-reduce a dense TP MLP would pay.  Compute is skewed by routing
    (an owner with hot experts works more — the RPC handler effect).
  * "onesided": data-to-compute.  Each rank all-gathers the expert weights
    (the one-sided READ of the remote region), takes 1/tp of the local
    tokens, runs the FULL MoE on them, and all-gathers outputs back.
    Compute is perfectly balanced; wire: weights + (B_loc,S,d) gather.
    Wins for small expert tables (granite: 32 x 3 x 1024 x 512).

Routing is capacity-based (drop on overflow, deterministic) — the TPU-static
analogue of the send-queue back-pressure in transport.route_by_dest, and the
same code shape: sort by destination, position-within-destination, scatter.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import cost_model
from repro.configs.base import ModelConfig
from repro.parallel.sharding import Topology


def _route(xt, probs_topv, topi, n_experts_local: int, e_offset, capacity: int):
    """Capacity-routed dispatch for one device's tokens.

    xt: (T, d); topv/topi: (T, K).  Returns (buf (E_l, C, d), meta) where
    meta lets the combine step gather results back.
    """
    T, K = topi.shape
    d = xt.shape[-1]
    flat_e = (topi.reshape(-1).astype(jnp.int32) - e_offset)         # (T*K,)
    w = probs_topv.reshape(-1)
    local = (flat_e >= 0) & (flat_e < n_experts_local)
    slot = jnp.where(local, flat_e, n_experts_local)                 # drop row
    onehot = slot[:, None] == jnp.arange(n_experts_local + 1)[None, :]
    pos = (jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1)[
        jnp.arange(T * K), slot]
    keep = local & (pos < capacity)
    dst_e = jnp.where(keep, slot, n_experts_local)
    dst_c = jnp.where(keep, pos, capacity)
    tok = jnp.arange(T * K, dtype=jnp.int32) // K
    buf = jnp.zeros((n_experts_local + 1, capacity + 1, d), xt.dtype)
    buf = buf.at[dst_e, dst_c].set(xt[tok])
    return buf[:n_experts_local, :capacity], (dst_e, dst_c, tok, w, keep)


def _combine(outbuf, meta, T: int, d: int):
    dst_e, dst_c, tok, w, keep = meta
    padded = jnp.pad(outbuf, ((0, 1), (0, 1), (0, 0)))
    rows = padded[dst_e, dst_c].astype(jnp.float32)                  # (T*K, d)
    rows = rows * jnp.where(keep, w, 0.0)[:, None]
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(rows)
    return out.astype(outbuf.dtype)


def _router(cfg: ModelConfig, xt, router_w):
    logits = jnp.einsum("td,de->te", xt, router_w,
                        preferred_element_type=jnp.float32)
    if cfg.router_renorm:   # deepseek: softmax-all -> top-k -> renormalize
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, cfg.top_k)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    else:                   # granite: top-k logits -> softmax over them
        tlog, topi = lax.top_k(logits, cfg.top_k)
        topv = jax.nn.softmax(tlog, axis=-1)
    return topv, topi


def _expert_ffn(buf, wg, wu, wd):
    """buf: (E, C, d); weights (E, d, f)/(E, f, d)."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_dispatch_mode(cfg: ModelConfig, topo: Topology, tokens_per_device: int) -> str:
    tp = topo.axis_sizes.get("model", 1)
    if tp == 1 or cfg.n_experts % tp != 0:
        return "local"
    choice = cost_model.moe_dispatch_choice(
        tokens_per_shard=tokens_per_device, d_model=cfg.d_model, d_ff=cfg.d_ff,
        n_experts=cfg.n_experts, top_k=cfg.top_k, shards=tp)
    return choice.mode


def moe_ffn(cfg: ModelConfig, topo: Topology, x, router_w, wg, wu, wd,
            mode: str = "auto"):
    """x: (B, S, d) batch-sharded / model-replicated.
    router_w: (d, E) replicated; wg/wu: (E, d, f); wd: (E, f, d) — E sharded
    over model.  Returns (B, S, d)."""
    B, S, d = x.shape
    tp = topo.axis_sizes.get("model", 1)
    dp = int(np.prod([topo.axis_sizes.get(a, 1) for a in ("pod", "data")]))
    E = cfg.n_experts

    if mode == "auto":
        mode = moe_dispatch_mode(cfg, topo, tokens_per_device=(B * S) // dp)
    if tp > 1 and not topo._mesh_axes_for("expert", E):
        # wide-DP rules (§Perf C2): every device holds ALL experts and routes
        # only its own tokens — zero dispatch collectives.
        mode = "replicated"

    if mode == "replicated":
        x_spec = topo.spec_for((B, S, d), ("batch", None, None))
        bax = x_spec[0]
        bax = (bax,) if isinstance(bax, str) else (bax or ())
        b_loc = B // int(np.prod([topo.axis_sizes[a] for a in bax])) if bax else B
        T_loc = b_loc * S
        C = max(1, int(np.ceil(T_loc * cfg.top_k / E * cfg.capacity_factor)))

        def repl_impl(xl, rw, g_, u_, d_):
            xt = xl.reshape(-1, d)
            topv, topi = _router(cfg, xt, rw)
            buf, meta = _route(xt, topv, topi, E, jnp.int32(0), C)
            out = _combine(_expert_ffn(buf, g_, u_, d_), meta, xt.shape[0], d)
            return out.reshape(xl.shape)

        rep = topo.spec_for(router_w.shape, (None, None))
        wspec = topo.spec_for(wg.shape, (None, None, None))
        return jax.shard_map(
            repl_impl, mesh=topo.mesh,
            in_specs=(x_spec, rep, wspec, wspec,
                      topo.spec_for(wd.shape, (None, None, None))),
            out_specs=x_spec, check_vma=False)(x, router_w, wg, wu, wd)

    if mode == "local" or tp == 1 or E % tp != 0:
        # single-shard fallback (smoke tests / 1-device CPU)
        xt = x.reshape(B * S, d)
        topv, topi = _router(cfg, xt, router_w)
        C = max(1, int(np.ceil(B * S * cfg.top_k / E * cfg.capacity_factor)))
        buf, meta = _route(xt, topv, topi, E, jnp.int32(0), C)
        out = _combine(_expert_ffn(buf, wg, wu, wd), meta, B * S, d)
        return out.reshape(B, S, d)

    E_l = E // tp
    x_spec = topo.spec_for((B, S, d), ("batch", None, None))
    r_spec = topo.spec_for(router_w.shape, (None, None))
    w3_spec = topo.spec_for(wg.shape, ("expert", None, None))
    ax0 = x_spec[0]
    ax0 = (ax0,) if isinstance(ax0, str) else (ax0 or ())
    b_loc = B // int(np.prod([topo.axis_sizes[a] for a in ax0])) if ax0 else B
    T_loc = b_loc * S
    if mode == "onesided" and T_loc % tp != 0:
        mode = "rpc"      # decode-sized batches: too few tokens to split

    if mode == "rpc":
        C = max(1, int(np.ceil(T_loc * cfg.top_k / E * cfg.capacity_factor)))

        def rpc_impl(xl, rw, g_, u_, d_):
            xt = xl.reshape(-1, d)
            topv, topi = _router(cfg, xt, rw)
            m = lax.axis_index("model").astype(jnp.int32)
            buf, meta = _route(xt, topv, topi, E_l, m * E_l, C)
            out = _combine(_expert_ffn(buf, g_, u_, d_), meta, xt.shape[0], d)
            out = lax.psum(out, "model")
            return out.reshape(xl.shape)

        return jax.shard_map(
            rpc_impl, mesh=topo.mesh,
            in_specs=(x_spec, r_spec, w3_spec, w3_spec,
                      topo.spec_for(wd.shape, ("expert", None, None))),
            out_specs=x_spec, check_vma=False)(x, router_w, wg, wu, wd)

    # ---- one-sided: all-gather weights, compute 1/tp of local tokens ------
    assert T_loc % tp == 0, (T_loc, tp)
    T_my = T_loc // tp
    C = max(1, int(np.ceil(T_my * cfg.top_k / E * cfg.capacity_factor)))

    def onesided_impl(xl, rw, g_, u_, d_):
        gf = lax.all_gather(g_, "model", axis=0, tiled=True)   # one-sided READ
        uf = lax.all_gather(u_, "model", axis=0, tiled=True)
        df = lax.all_gather(d_, "model", axis=0, tiled=True)
        xt = xl.reshape(-1, d)
        m = lax.axis_index("model")
        x_my = lax.dynamic_slice_in_dim(xt, m * T_my, T_my, axis=0)
        topv, topi = _router(cfg, x_my, rw)
        buf, meta = _route(x_my, topv, topi, E, jnp.int32(0), C)
        out_my = _combine(_expert_ffn(buf, gf, uf, df), meta, T_my, d)
        out = lax.all_gather(out_my, "model", axis=0, tiled=True)
        return out.reshape(xl.shape)

    return jax.shard_map(
        onesided_impl, mesh=topo.mesh,
        in_specs=(x_spec, r_spec, w3_spec, w3_spec,
                  topo.spec_for(wd.shape, ("expert", None, None))),
        out_specs=x_spec, check_vma=False)(x, router_w, wg, wu, wd)
