from repro.models import api, layers, mamba2, moe, transformer, whisper, zamba  # noqa: F401
