"""NIC connection-state model: QP modes and the NIC-cache hit model
(Storm §2.2, §3.4, Fig. 7).

Storm's scaling argument is about CONNECTION STATE, not bytes: every reliable
connection (QP) pins ~375 B of state on the NIC, the NIC caches that state in
a ~2 MiB on-chip cache, and once the cluster grows past the point where the
working set of QP state overflows the cache, every op risks a PCIe fetch of
evicted state.  The mitigations the paper analyses are exactly the three
*connection modes* modeled here:

  * ``rc_exclusive`` — sibling-thread RC (§3.4): every thread owns a private
    QP to every remote thread, conns/node = 2·m·t.  Lock-free and fastest at
    rack scale, but QP state grows with cluster size × thread count and blows
    through the NIC cache beyond ~64 nodes at 20 threads (Fig. 7).
  * ``rc_shared``   — QP sharing across the t sibling threads of one process
    (RDMAvisor-style): conns/node = 2·m, a t-fold state reduction, paid for
    with a modeled per-op synchronization cost that grows with the number of
    sharers (threads serialize on the shared send queue).
  * ``dct``         — dynamically connected transport: O(1) connection state
    per node (one initiator context per thread + one target context),
    INDEPENDENT of cluster size, paid for with a per-message reconnect
    latency (the DC connect/disconnect handshake rides every message train).

Calibration (single source of truth — the constants formerly inlined in
``benchmarks/fig7_emulation.py`` live HERE and nowhere else):

  * ``qp_bytes = 375``        — RC QP state (§2.1);
  * ``qp_cache_bytes = 1 MiB``— the slice of the ~2 MiB NIC cache available
    for QP state (the rest holds WQE/MTT/MPT entries);
  * ``pcie_us = 0.20``        — cost of a PCIe fetch of evicted QP state,
    chosen so the 20-thread RC curve drops 1.57x at 96 nodes (the paper's
    Fig. 7 number) while the 10-thread curve stays flat to 128 nodes; both
    behaviours then EMERGE from the model at every other sweep point;
  * ``share_lock_us``/``share_contention`` — QP-sharing cost: a base
    lock/unlock plus a linear contention term per extra sharer, calibrated so
    sharing LOSES to exclusive RC inside the rack but wins ≥1.3x at 96
    nodes/20 threads (the paper's guideline: share only beyond rack scale);
  * ``dct_reconnect_us``      — per-op reconnect cost, calibrated likewise.

``ConnTable`` is the per-node connection accounting for one (mode, nodes,
threads) point; the protocol stack threads it through ``wire_for`` /
``wire_for_classes`` so every :class:`~repro.core.transport.WireStats`
carries the modeled NIC-cache hit rate and per-op penalty of the transport
configuration it ran under.

Public API: ``NicModel`` (calibration constants), ``ConnTable``
(``conns_per_node`` / ``state_bytes`` / ``cache_hit`` /
``penalty_us_per_op`` / ``describe``), the mode names ``RC_EXCLUSIVE`` /
``RC_SHARED`` / ``DCT`` (``MODES``) and the ``sweep`` generator.  Invariant:
a ``nic=ConnTable`` threaded through any dataplane call PRICES the transport
— protocol results are bit-identical with and without it
(tests/test_nic_model.py).
"""
from __future__ import annotations

import dataclasses

# Connection modes (ConnMode values)
RC_EXCLUSIVE = "rc_exclusive"
RC_SHARED = "rc_shared"
DCT = "dct"
MODES = (RC_EXCLUSIVE, RC_SHARED, DCT)


@dataclasses.dataclass(frozen=True)
class NicModel:
    """Calibration constants of the NIC-cache / connection-cost model."""
    qp_bytes: int = 375               # RC QP state bytes (§2.1)
    dct_bytes: int = 192              # DC initiator/target context bytes
    qp_cache_bytes: float = 1.0 * 1024 * 1024   # NIC cache slice for QP state
    pcie_us: float = 0.20             # DMA fetch of evicted QP state, per op
    share_lock_us: float = 0.003      # QP-sharing base lock cost, per op
    share_contention: float = 0.05    # extra cost fraction per extra sharer
    dct_reconnect_us: float = 0.006   # DC connect/disconnect cost, per op


@dataclasses.dataclass(frozen=True)
class ConnTable:
    """Per-node connection state for one (mode, cluster size, threads) point.

    Static (trace-time) Python object: the hit rate and per-op penalty are
    plain floats, so they fold into jitted protocol code as constants — the
    TPU analogue of "the QP mode is fixed when the cluster is wired up".
    """
    n_nodes: int
    threads: int = 1
    mode: str = RC_EXCLUSIVE
    model: NicModel = NicModel()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown connection mode {self.mode!r}; "
                             f"expected one of {MODES}")
        if self.n_nodes < 1 or self.threads < 1:
            raise ValueError(f"n_nodes and threads must be >= 1, got "
                             f"{self.n_nodes}/{self.threads}")

    # ---- connection accounting ---------------------------------------------
    @property
    def conns_per_node(self) -> int:
        """Connections (QP/DC contexts) each node's NIC must hold state for."""
        if self.mode == RC_EXCLUSIVE:
            return 2 * self.n_nodes * self.threads     # sibling-thread RC
        if self.mode == RC_SHARED:
            return 2 * self.n_nodes                    # t-fold sharing
        return self.threads + 1                        # DCT: O(1) in n_nodes

    @property
    def state_bytes(self) -> int:
        """QP/DC state bytes resident for this node's connections."""
        per_conn = self.model.dct_bytes if self.mode == DCT else self.model.qp_bytes
        return self.conns_per_node * per_conn

    # ---- NIC-cache hit model -----------------------------------------------
    @property
    def cache_hit(self) -> float:
        """Modeled NIC-cache hit rate for connection-state accesses."""
        return min(1.0, self.model.qp_cache_bytes / max(self.state_bytes, 1))

    @property
    def mode_cost_us(self) -> float:
        """Per-op cost intrinsic to the mode (sharing locks, DC reconnects)."""
        if self.mode == RC_SHARED:
            return self.model.share_lock_us * (
                1.0 + self.model.share_contention * (self.threads - 1))
        if self.mode == DCT:
            return self.model.dct_reconnect_us
        return 0.0

    @property
    def penalty_us_per_op(self) -> float:
        """Total modeled per-op penalty: PCIe fetches of evicted QP state
        (cache misses) plus the mode-intrinsic cost."""
        return (1.0 - self.cache_hit) * self.model.pcie_us + self.mode_cost_us

    def describe(self) -> str:
        return (f"{self.mode}[m={self.n_nodes},t={self.threads}]: "
                f"conns/node={self.conns_per_node} "
                f"state={self.state_bytes / 1024:.0f}KiB "
                f"hit={self.cache_hit:.3f} "
                f"penalty={self.penalty_us_per_op:.4f}us/op")


def sweep(node_counts, thread_counts, modes=MODES, model: NicModel = NicModel()):
    """Yield a ConnTable per (mode, nodes, threads) sweep point."""
    for mode in modes:
        for t in thread_counts:
            for m in node_counts:
                yield ConnTable(n_nodes=m, threads=t, mode=mode, model=model)
