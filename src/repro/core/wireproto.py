"""Wire protocol registry: every opcode and reply status in ONE place.

Storm registers each data structure's operations with the dataplane
(Table 3: ``rpc_handler`` per structure); the wire-level contract between
client-built request records and owner-side handlers is the opcode in word 0
of the record and the status in word 0 of the reply.  Those constants used to
be scattered across ``rpc.py`` / ``tx.py`` / ``datastructs/hashtable.py`` —
this module is the single registration point, so a new data structure (e.g.
the ordered B-link index, ``datastructs/btree.py``) claims its opcode block
here and every layer agrees on the numbering by construction.

``rpc.py`` re-exports everything for backward compatibility (``R.OP_LOOKUP``
keeps working), but core modules import this module directly.

Opcode blocks (8 opcodes per block; claim the next free block for a new
subsystem — ``assert_unique_opcodes`` below catches collisions at import):

  ======== =========== ====================================================
  block    opcodes     subsystem
  ======== =========== ====================================================
   0 –  7  OP_NOP..    dataplane + hash table (Storm §5.4/§5.5)
   8 – 15  OP_READ_..  replication / validation fallback (PR 4)
  16 – 23  OP_BT_*     ordered index (B-link tree, ``datastructs/btree.py``)
  24 – 31  OP_PL_*     placement & membership (``core/placement.py``)
  ======== =========== ====================================================

Statuses are shared by every handler: word 0 of every reply is one of the
``ST_*`` codes below.  ``ST_DROPPED`` is special — it is stamped by the
TRANSPORT (roundsched) for requests that were never delivered (send-queue
overflow or parked lane), so it can never alias a handler-returned status.
"""
from __future__ import annotations

# --- dataplane + hash table opcodes (word 0 of every request record) -------
OP_NOP = 0
OP_LOOKUP = 1
OP_INSERT = 2
OP_UPDATE = 3
OP_DELETE = 4
OP_LOCK = 5           # lock write-set entry (returns version at lock time)
OP_COMMIT_UNLOCK = 6  # install value, version += 2, unlock
OP_ABORT_UNLOCK = 7   # release lock without installing

# --- replication / validation fallback block -------------------------------
OP_READ_VERSION = 8   # validation re-read by RPC (fallback path)
OP_BACKUP_WRITE = 9   # install a committed record image on a backup replica

# --- ordered index (B-link tree) opcodes -----------------------------------
OP_BT_LOOKUP = 16     # point lookup (owner-side separator walk)
OP_BT_INSERT = 17     # upsert; may split a full leaf (B-link structural op)
OP_BT_DELETE = 18     # remove a key (no structural merge — leaves persist)
OP_BT_LOCK = 19       # lock the key's LEAF for a tx write (pre-splits a full
                      # leaf so the later commit can never lack space)
OP_BT_COMMIT = 20     # install the write into the locked leaf, bump leaf
                      # version, unlock
OP_BT_ABORT = 21      # release the leaf lock without installing
OP_BT_SCAN = 22       # return the full image of the leaf covering a key
                      # (the range-scan RPC fallback; read-only)
OP_BT_BACKUP = 23     # install a committed (key, value) on a backup replica's
                      # own tree (logical replication of the ordered index)

# --- placement & membership opcodes -----------------------------------------
OP_PL_INSTALL = 24    # install one partition's routing row (+ epoch + alive
                      # bitmap) into the owner-published routing region; the
                      # coordinator broadcasts these on every epoch bump

# --- reply status codes (word 0 of every reply) ----------------------------
ST_OK = 0
ST_NOT_FOUND = 1
ST_LOCK_FAIL = 2
ST_NO_SPACE = 3   # handler-returned: storage full (request WAS delivered)
ST_BAD_OP = 4
ST_DROPPED = 5    # transport-level: request never delivered (send-queue
                  # overflow or parked lane) — retryable back-pressure,
                  # distinct from the permanent ST_NO_SPACE
ST_WRONG_EPOCH = 6  # handler-returned by lock-class ops when the client's
                    # routing table is stale (this node no longer owns the
                    # key's partition) — the lane aborts with cause
                    # ``stale_route``, refreshes its PlacementTable, retries


def assert_unique_opcodes():
    """Self-check: no two ``OP_*`` constants (or two ``ST_*`` constants)
    share a number.  Runs at import so a new opcode block that collides with
    an existing one fails loudly instead of silently aliasing handlers."""
    for prefix in ("OP_", "ST_"):
        seen = {}
        for name, val in sorted(globals().items()):
            if not name.startswith(prefix) or not isinstance(val, int):
                continue
            if val in seen:
                raise AssertionError(
                    f"wireproto collision: {name} and {seen[val]} are both "
                    f"{val}")
            seen[val] = name


assert_unique_opcodes()
