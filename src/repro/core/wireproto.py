"""Wire protocol registry: every opcode and reply status in ONE place.

Storm registers each data structure's operations with the dataplane
(Table 3: ``rpc_handler`` per structure); the wire-level contract between
client-built request records and owner-side handlers is the opcode in word 0
of the record and the status in word 0 of the reply.  Those constants used to
be scattered across ``rpc.py`` / ``tx.py`` / ``datastructs/hashtable.py`` —
this module is the single registration point, so a new data structure (e.g.
the ordered B-link index, ``datastructs/btree.py``) claims its opcode block
here and every layer agrees on the numbering by construction.

``rpc.py`` re-exports everything for backward compatibility (``R.OP_LOOKUP``
keeps working), but core modules import this module directly.

Opcode blocks:
  *  0 –  9  dataplane + hash table (Storm §5.4/§5.5 + PR-4 replication)
  * 16 – 23  ordered index (B-link tree, ``datastructs/btree.py``)

Statuses are shared by every handler: word 0 of every reply is one of the
``ST_*`` codes below.  ``ST_DROPPED`` is special — it is stamped by the
TRANSPORT (roundsched) for requests that were never delivered (send-queue
overflow or parked lane), so it can never alias a handler-returned status.
"""
from __future__ import annotations

# --- dataplane + hash table opcodes (word 0 of every request record) -------
OP_NOP = 0
OP_LOOKUP = 1
OP_INSERT = 2
OP_UPDATE = 3
OP_DELETE = 4
OP_LOCK = 5           # lock write-set entry (returns version at lock time)
OP_COMMIT_UNLOCK = 6  # install value, version += 2, unlock
OP_ABORT_UNLOCK = 7   # release lock without installing
OP_READ_VERSION = 8   # validation re-read by RPC (fallback path)
OP_BACKUP_WRITE = 9   # install a committed record image on a backup replica

# --- ordered index (B-link tree) opcodes -----------------------------------
OP_BT_LOOKUP = 16     # point lookup (owner-side separator walk)
OP_BT_INSERT = 17     # upsert; may split a full leaf (B-link structural op)
OP_BT_DELETE = 18     # remove a key (no structural merge — leaves persist)
OP_BT_LOCK = 19       # lock the key's LEAF for a tx write (pre-splits a full
                      # leaf so the later commit can never lack space)
OP_BT_COMMIT = 20     # install the write into the locked leaf, bump leaf
                      # version, unlock
OP_BT_ABORT = 21      # release the leaf lock without installing
OP_BT_SCAN = 22       # return the full image of the leaf covering a key
                      # (the range-scan RPC fallback; read-only)
OP_BT_BACKUP = 23     # install a committed (key, value) on a backup replica's
                      # own tree (logical replication of the ordered index)

# --- reply status codes (word 0 of every reply) ----------------------------
ST_OK = 0
ST_NOT_FOUND = 1
ST_LOCK_FAIL = 2
ST_NO_SPACE = 3   # handler-returned: storage full (request WAS delivered)
ST_BAD_OP = 4
ST_DROPPED = 5    # transport-level: request never delivered (send-queue
                  # overflow or parked lane) — retryable back-pressure,
                  # distinct from the permanent ST_NO_SPACE
