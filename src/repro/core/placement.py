"""Placement & membership: epoch-stamped routing as a first-class subsystem.

Storm keeps per-connection state small precisely so that ROUTING state can be
client-cached: a client that knows (partition -> owner, backups) talks to the
dataplane with zero metadata round trips.  This module extracts every
owner/replica/routing decision — previously smeared across
``transport.route_by_dest`` call sites, ``ReplicaConfig.replica_of`` ring
math, and the ad-hoc ``failover_dest`` / ``failover_lookup`` helpers — into
one epoch-stamped ``PlacementTable``:

  * **The table** maps each of ``n_parts`` partitions (== the provisioned
    node-slot count; elastic membership operates within that static ceiling,
    the standard slot model) to an ordered copy list: column 0 is the OWNER
    (the only node that accepts lock-class ops for the partition), columns
    1.. are the backups, -1 = unused slot.  Plus a cluster liveness mask and
    a monotonically increasing ``epoch``.

  * **Publication** mirrors the btree separator-directory idiom: every node
    carries a ``routing`` region in its arena (the coordinator-published
    image), and ``refresh_table`` is ONE one-sided read of that region —
    "The Impact of RDMA on Agreement" (PAPERS.md) is the grounding for
    driving membership decisions with one-sided primitives.

  * **Staleness is owner-checked**: the serial handlers compare the partition
    owner recorded in their OWN routing region against their node id for
    lock-class ops (OP_LOCK / OP_INSERT / OP_UPDATE / OP_DELETE and the
    btree structural/lock ops).  A request routed with a stale table gets
    ``ST_WRONG_EPOCH``; the lane aborts with cause ``stale_route``, refreshes
    its table (``txloop``), and retries — exactly like a stale separator.
    COMMIT/ABORT-class ops are deliberately UNCHECKED (an acquired lock must
    always be releasable, and a commit's install target is wherever the lock
    was granted), as are reads (version-validated) and driver-directed backup
    installs.  The epoch conceptually rides the existing 1-word message
    header (see ``transport.wire_for``), so the epoch-stable wire format and
    round schedule are bit-identical to the pre-placement dataplane.

  * **Membership**: ``kill_node`` / ``join_node`` / ``leave_node`` bump the
    epoch and emit a new table; ``repair_plan`` + ``rereplicate`` restore the
    replication factor after a failure by streaming the dead node's
    partitions to new backups via the existing OP_BACKUP_WRITE / OP_BT_BACKUP
    classes; ``migrate_partition`` moves a partition transactionally
    (source-lock -> copy -> epoch flip) on the OCC machinery itself, so a
    rebalance concurrent with committing transactions loses no write: any
    key (hash) or leaf (btree) with an in-flight client lock makes the
    migration's own locks fail and the whole migration aborts cleanly.

Layering: this module sits ABOVE transport/onesided/rpc and BELOW
replication/tx — ``replication.py`` is now a thin policy (its ring placement
is expressed as a table via ``table_from_replica`` and its failover helpers
delegate here).  The data-structure modules are imported lazily to keep the
dependency graph acyclic (they import this module for the region codec).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import onesided as osd
from repro.core import rpc as R
from repro.core import telemetry as T
from repro.core import slots as sl
from repro.core import wireproto as W
from repro.core.transport import Transport, WireStats, placement_dest

# Static ceiling on copies per partition (owner + up to 3 backups) — what
# bounds the published routing-region size and the install record layout.
MAX_COPIES = 4
NONE = 0xFFFFFFFF          # "no copy in this slot" in the arena image

# routing-region word layout (relative to layout["routing"].base):
EPOCH_WORD = 0             # current epoch
NPARTS_WORD = 1            # n_parts (sanity / decoder self-description)
SELF_WORD = 2              # THIS node's id — what the owner check compares
COPIES_WORD = 3            # n_parts rows of MAX_COPIES words, then alive bits

# lock tag used by migration's source-lock phase (nonzero, and outside the
# per-lane tag space tx.py generates)
MIG_TAG = 0xB1C00000


def alive_words(n_nodes: int) -> int:
    return (n_nodes + 31) // 32


def routing_words(n_nodes: int) -> int:
    """Published routing-region size in words (n_parts == n_nodes)."""
    return COPIES_WORD + n_nodes * MAX_COPIES + alive_words(n_nodes)


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    """Static placement parameters (trace-time).

    n_nodes: provisioned node-slot count — also the partition count (each
             initial node owns exactly one partition; membership changes
             re-home partitions but never re-shard the key space).
    f:       backup copies per partition (f + 1 copies total).
    """
    n_nodes: int
    f: int = 0

    def __post_init__(self):
        if not 0 <= self.f < self.n_nodes:
            raise ValueError(
                f"placement needs 0 <= f < n_nodes (got f={self.f}, "
                f"n_nodes={self.n_nodes})")
        if self.f + 1 > MAX_COPIES:
            raise ValueError(
                f"f={self.f} exceeds MAX_COPIES={MAX_COPIES} copies")

    @property
    def n_parts(self) -> int:
        return self.n_nodes

    @property
    def n_copies(self) -> int:
        return self.f + 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PlacementTable:
    """The client-cached routing state (a pytree; shared across a
    SimTransport's clients — they all read the same coordinator bytes)."""
    epoch: jnp.ndarray    # ()           uint32
    copies: jnp.ndarray   # (n_parts, K) int32 — col 0 = owner, -1 = none
    alive: jnp.ndarray    # (n_nodes,)   bool


def initial_table(pcfg: PlacementConfig) -> PlacementTable:
    """Epoch-0 identity table: partition p is owned by node p with its f
    backups on the ring — exactly ``ReplicaConfig``'s placement, so routing
    through this table is bit-identical to the static partition math."""
    p = np.arange(pcfg.n_parts)[:, None]
    i = np.arange(MAX_COPIES)[None, :]
    copies = np.where(i < pcfg.n_copies, (p + i) % pcfg.n_nodes, -1)
    return PlacementTable(
        epoch=jnp.uint32(0),
        copies=jnp.asarray(copies, jnp.int32),
        alive=jnp.ones((pcfg.n_nodes,), bool))


def table_from_replica(rep, alive) -> PlacementTable:
    """Express a ``ReplicaConfig`` (ring rotation or a test's pathological
    placement fn) + liveness mask as a PlacementTable, so every failover
    decision reduces to the ONE first-live-copy scan
    (``transport.placement_dest``)."""
    n = rep.n_nodes
    p = jnp.arange(n, dtype=jnp.int32)
    cols = [rep.replica_of(p, i).astype(jnp.int32) for i in range(rep.n_copies)]
    while len(cols) < MAX_COPIES:
        cols.append(jnp.full((n,), -1, jnp.int32))
    return PlacementTable(epoch=jnp.uint32(0),
                          copies=jnp.stack(cols, axis=1),
                          alive=jnp.asarray(alive, bool))


# ---------------------------------------------------------------------------
# Routing queries (all traced; part may be any batch shape)
# ---------------------------------------------------------------------------
def owner_of(table: PlacementTable, part):
    """The partition's owner — the only valid target for lock-class ops."""
    return table.copies[jnp.asarray(part, jnp.int32), 0]


def owner_dest(table: PlacementTable, part):
    """Owner if alive, else -1 (parked by route_by_dest -> ST_DROPPED).
    A dead owner means writes are unavailable until repair promotes a
    backup — primary-backup semantics, never a silent write to a replica."""
    own = owner_of(table, part)
    ok = (own >= 0) & table.alive[jnp.clip(own, 0, table.alive.shape[0] - 1)]
    return jnp.where(ok, own, -1).astype(jnp.int32)


def copy_nodes(table: PlacementTable, part):
    """All copy slots of a partition: (..., K) int32 (-1 = none)."""
    return table.copies[jnp.asarray(part, jnp.int32)]


def live_dest(table: PlacementTable, part):
    """(dest, reachable): first LIVE copy in owner-priority order — the read
    fail-over rule (owner when everything is up)."""
    return placement_dest(table.copies, table.alive, part)


# ---------------------------------------------------------------------------
# Region codec: PlacementTable <-> published routing-region words
# ---------------------------------------------------------------------------
def _alive_bits(n_nodes: int, alive) -> jnp.ndarray:
    idx = jnp.arange(n_nodes)
    bits = jnp.zeros((alive_words(n_nodes),), jnp.uint32)
    return bits.at[idx // 32].add(
        jnp.asarray(alive, jnp.uint32) << (idx % 32).astype(jnp.uint32))


def region_image(pcfg: PlacementConfig, table: PlacementTable) -> jnp.ndarray:
    """(routing_words,) uint32 image of the published region.  The SELF_WORD
    is left 0 — init/install preserve each node's own id."""
    cps = jnp.where(table.copies >= 0, table.copies.astype(jnp.uint32),
                    jnp.uint32(NONE))
    head = jnp.stack([jnp.asarray(table.epoch, jnp.uint32),
                      jnp.uint32(pcfg.n_parts), jnp.uint32(0)])
    return jnp.concatenate(
        [head, cps.reshape(-1), _alive_bits(pcfg.n_nodes, table.alive)])


def identity_region_image(n_nodes: int) -> jnp.ndarray:
    """The epoch-0 image the data structures install at init (f-agnostic:
    the full ring is published; decoders mask copies beyond their pcfg.f,
    and the owner check only ever reads column 0)."""
    pcfg = PlacementConfig(n_nodes, f=min(MAX_COPIES, n_nodes) - 1)
    return region_image(pcfg, initial_table(pcfg))


def decode_region(pcfg: PlacementConfig, words) -> PlacementTable:
    """Inverse of region_image (SELF_WORD ignored; copy slots beyond
    pcfg.n_copies masked to -1 so the decode is pcfg-consistent)."""
    n = pcfg.n_nodes
    cps = words[COPIES_WORD:COPIES_WORD + n * MAX_COPIES].reshape(
        n, MAX_COPIES).astype(jnp.int32)
    col_ok = jnp.arange(MAX_COPIES) < pcfg.n_copies
    copies = jnp.where(col_ok[None, :], cps, -1)
    bw = words[COPIES_WORD + n * MAX_COPIES:
               COPIES_WORD + n * MAX_COPIES + alive_words(n)]
    idx = jnp.arange(n)
    alive = ((bw[idx // 32] >> (idx % 32).astype(jnp.uint32)) & 1).astype(bool)
    return PlacementTable(epoch=words[EPOCH_WORD].astype(jnp.uint32),
                          copies=copies, alive=alive)


# ---------------------------------------------------------------------------
# Publication: refresh (one-sided read) and install (RPC broadcast / local)
# ---------------------------------------------------------------------------
def refresh_table(t: Transport, state, layout, pcfg: PlacementConfig,
                  table: PlacementTable, *, enabled=None, nic=None,
                  telemetry=None):
    """Refresh the client-cached table with ONE one-sided read of the
    coordinator-published routing region (the lowest live node per the
    CURRENT — possibly stale — table; a freshly-dead coordinator is caught
    on the next retry once the read returns its successor's view).

    enabled: optional scalar/() bool — when False the read issues nothing
    (zero wire, zero round trips) and the decoded result is garbage; callers
    select old-vs-new with a tree_map, mirroring btree.refresh_meta's
    retry-round gating.  Returns (table, WireStats)."""
    n_local = t.n_local
    rb = layout["routing"].base
    length = routing_words(pcfg.n_nodes)
    coord = jnp.argmax(table.alive).astype(jnp.int32)   # first live node
    dest = jnp.full((n_local, 1), coord, jnp.int32)
    off = jnp.full((n_local, 1), rb, jnp.uint32)
    en = None
    if enabled is not None:
        en = jnp.broadcast_to(jnp.asarray(enabled, bool), (n_local, 1))
    buf, _, stats = osd.remote_read(t, state["arena"], dest, off,
                                    length=length, enabled=en, nic=nic,
                                    telemetry=telemetry, phase=T.PH_REFRESH)
    # every SimTransport client reads identical coordinator bytes -> decode
    # one lane into the one shared table
    return decode_region(pcfg, buf[0, 0]), stats


def install_records(pcfg: PlacementConfig, table: PlacementTable):
    """(n_parts, record_words) OP_PL_INSTALL records — one per partition:
    [op, part, epoch, 0, copies row (MAX_COPIES) ++ alive bits ++ 0...]."""
    n = pcfg.n_parts
    rows = jnp.where(table.copies[:, :MAX_COPIES] >= 0,
                     table.copies[:, :MAX_COPIES].astype(jnp.uint32),
                     jnp.uint32(NONE))
    bits = jnp.broadcast_to(_alive_bits(pcfg.n_nodes, table.alive)[None],
                            (n, alive_words(pcfg.n_nodes)))
    pad = jnp.zeros((n, sl.VALUE_WORDS - MAX_COPIES
                     - alive_words(pcfg.n_nodes)), jnp.uint32)
    value = jnp.concatenate([rows, bits, pad], axis=-1)
    part = jnp.arange(n, dtype=jnp.uint32)
    epoch = jnp.broadcast_to(jnp.asarray(table.epoch, jnp.uint32), (n,))
    head = jnp.stack([jnp.full((n,), W.OP_PL_INSTALL, jnp.uint32),
                      part, epoch, jnp.zeros((n,), jnp.uint32)], axis=-1)
    return jnp.concatenate([head, value], axis=-1)


def install_table(t: Transport, state, layout, pcfg: PlacementConfig,
                  table: PlacementTable, handler, *, targets=None,
                  issuer: int = 0, capacity: Optional[int] = None, nic=None):
    """Broadcast the table to ``targets`` (node-id list; default: every node
    slot) as OP_PL_INSTALL RPCs from ``issuer`` — the wire-honest path the
    membership/migration drivers use.  Returns (state, WireStats)."""
    tg = (list(range(pcfg.n_nodes)) if targets is None
          else [int(x) for x in targets])
    recs1 = install_records(pcfg, table)                       # (P, Wrec)
    B = len(tg) * pcfg.n_parts
    dest_row = jnp.repeat(jnp.asarray(tg, jnp.int32), pcfg.n_parts)
    recs_row = jnp.tile(recs1, (len(tg), 1))
    n_local = t.n_local
    dest = jnp.broadcast_to(dest_row[None], (n_local, B))
    recs = jnp.broadcast_to(recs_row[None], (n_local, B, recs1.shape[-1]))
    en = ((t.node_ids() == issuer)[:, None]
          & jnp.ones((1, B), bool))
    state, _, _, stats = R.rpc_call(t, state, dest, recs, handler,
                                    capacity=capacity, enabled=en, nic=nic)
    return state, stats


def install_local(state, layout, pcfg: PlacementConfig, table: PlacementTable,
                  nodes=None):
    """Write the table straight into the routing regions (no wire) — test
    setup / the coordinator updating its own published copy."""
    rb = layout["routing"].base
    length = routing_words(pcfg.n_nodes)
    arena = state["arena"]
    n_local = arena.shape[0]
    img = jnp.broadcast_to(region_image(pcfg, table)[None], (n_local, length))
    img = img.at[:, SELF_WORD].set(arena[:, rb + SELF_WORD])
    if nodes is not None:
        mask = jnp.zeros((n_local,), bool).at[jnp.asarray(nodes)].set(True)
        img = jnp.where(mask[:, None], img, arena[:, rb:rb + length])
    return {**state, "arena": arena.at[:, rb:rb + length].set(img)}


# ---------------------------------------------------------------------------
# Membership: epoch-bumping table transitions + the repair planner
# ---------------------------------------------------------------------------
def kill_node(pcfg: PlacementConfig, table: PlacementTable,
              node) -> PlacementTable:
    """Failure: mark dead, bump the epoch.  Routing immediately fails over
    reads (live_dest) and parks writes to partitions the node owned until
    ``repair_plan`` promotes a backup."""
    return PlacementTable(table.epoch + 1, table.copies,
                          table.alive.at[jnp.asarray(node)].set(False))


def join_node(pcfg: PlacementConfig, table: PlacementTable,
              node) -> PlacementTable:
    """(Re)join: mark live, bump the epoch.  The joiner serves no partition
    until ``migrate_partition`` / ``repair_plan`` route one to it."""
    return PlacementTable(table.epoch + 1, table.copies,
                          table.alive.at[jnp.asarray(node)].set(True))


def leave_node(pcfg: PlacementConfig, table: PlacementTable,
               node) -> PlacementTable:
    """Graceful departure — same table transition as ``kill_node``, but the
    caller is expected to drain first (``drain_plan`` + migrate each owned
    partition away), so no committed data becomes under-replicated."""
    return kill_node(pcfg, table, node)


def drain_plan(pcfg: PlacementConfig, table: PlacementTable, node: int):
    """Partitions owned by ``node`` with a suggested new owner each (the
    next live node on the ring that holds no copy yet) — the graceful-leave
    recipe: ``migrate_partition`` each, then ``leave_node``."""
    copies = np.asarray(table.copies)
    alive = np.asarray(table.alive)
    out = []
    for p in range(pcfg.n_parts):
        if copies[p, 0] != node:
            continue
        row = {int(c) for c in copies[p] if c >= 0}
        for step in range(1, pcfg.n_nodes):
            c = (p + step) % pcfg.n_nodes
            if c != node and alive[c] and c not in row:
                out.append((p, c))
                break
    return out


def repair_plan(pcfg: PlacementConfig, table: PlacementTable):
    """Re-replication planner (host-level, deterministic): for every
    partition with dead copies, promote the first surviving copy to owner
    and refill the copy list with live ring successors.

    Returns (new_table, transfers) where transfers is a list of
    (part, src, dst): stream partition ``part`` from live copy ``src`` to
    new backup ``dst`` (``rereplicate`` executes them).  A partition whose
    EVERY copy is dead is left as-is (unrecoverable: routed lanes park).
    The epoch bumps iff anything changed."""
    copies = np.asarray(table.copies)
    alive = np.asarray(table.alive)
    new = copies.copy()
    transfers = []
    changed = False
    for p in range(pcfg.n_parts):
        row = [int(c) for c in copies[p] if c >= 0]
        live_row = [c for c in row if alive[c]]
        if live_row == row and len(live_row) >= pcfg.n_copies:
            continue
        if not live_row:
            continue
        newrow = list(live_row)
        for step in range(1, pcfg.n_nodes):
            if len(newrow) >= pcfg.n_copies:
                break
            c = (p + step) % pcfg.n_nodes
            if alive[c] and c not in newrow:
                transfers.append((p, newrow[0], c))
                newrow.append(c)
        if newrow == row:
            continue
        new[p, :] = newrow + [-1] * (copies.shape[1] - len(newrow))
        changed = True
    if not changed:
        return table, []
    return PlacementTable(table.epoch + 1, jnp.asarray(new, jnp.int32),
                          table.alive), transfers


# ---------------------------------------------------------------------------
# Data movement: re-replication streaming + transactional migration
# ---------------------------------------------------------------------------
def _ds_for(cfg):
    from repro.core.datastructs import btree as bt
    from repro.core.datastructs import hashtable as ht
    if isinstance(cfg, ht.HashTableConfig):
        return ht, "hash"
    if isinstance(cfg, bt.BTreeConfig):
        return bt, "btree"
    raise TypeError(f"unknown data-structure config {type(cfg).__name__}")


def _read_region_images(t, state, layout, dest_node, puller, offsets, length,
                        nic=None):
    """One-sided bulk read: ``puller`` reads ``len(offsets)`` images of
    ``length`` words each from ``dest_node``.  Returns (images np, stats)."""
    B = offsets.shape[0]
    n_local = t.n_local
    dest = jnp.full((n_local, B), dest_node, jnp.int32)
    off = jnp.broadcast_to(offsets[None].astype(jnp.uint32), (n_local, B))
    en = jnp.broadcast_to((t.node_ids() == puller)[:, None], (n_local, B))
    buf, _, stats = osd.remote_read(t, state["arena"], dest, off,
                                    length=length, enabled=en, nic=nic)
    return np.asarray(jax.device_get(buf[puller])), stats


def _enumerate_hash(cfg, layout, images, part):
    """Clean, in-partition records from a full slot sweep (np host-side).
    Returns dict of np arrays (key_lo, key_hi, version, value, locked)."""
    from repro.core.datastructs import hashtable as ht
    klo = images[:, sl.KEY_LO]
    khi = images[:, sl.KEY_HI]
    ver = images[:, sl.VERSION]
    lock = images[:, sl.LOCK]
    present = klo != np.uint32(sl.EMPTY_KEY)
    in_part = np.asarray(ht.part_of(cfg, jnp.asarray(klo), jnp.asarray(khi))
                         ) == part
    sel = present & in_part
    return dict(key_lo=klo, key_hi=khi, version=ver,
                value=images[:, sl.VALUE0:], lock=lock, sel=sel,
                clean=sel & (ver % 2 == 0))


def rereplicate(t: Transport, state, cfg, layout, pcfg: PlacementConfig,
                transfers, *, nic=None):
    """Execute ``repair_plan`` transfers: for each (part, src, dst), the new
    backup ``dst`` pulls the partition's records from the surviving copy
    ``src`` with one-sided reads, then installs them through the existing
    backup classes (OP_BACKUP_WRITE byte-equal images for the hash table,
    OP_BT_BACKUP logical upserts for the btree).

    Install the repaired table (``install_table``) BEFORE streaming: new
    commits then already fan out to ``dst``, and any record committed while
    the stream is in flight is (re)installed by its own commit's backup
    class — the stream only has to carry the pre-failure state.  Locked or
    mid-commit (odd-version) records are skipped for the same reason.

    Returns (state, WireStats) — the stats are the re-replication bytes the
    membership benchmark reports."""
    ds, kind = _ds_for(cfg)
    handler = ds.make_rpc_handler(cfg, layout)
    total = WireStats.zero()
    for part, src, dst in transfers:
        part, src, dst = int(part), int(src), int(dst)
        if kind == "hash":
            offs = jnp.asarray(
                [int(ds.slot_idx_offset(layout, jnp.uint32(i)))
                 for i in range(cfg.n_slots)], jnp.uint32)
            images, s = _read_region_images(t, state, layout, src, dst, offs,
                                            sl.SLOT_WORDS, nic=nic)
            total = total + s
            e = _enumerate_hash(cfg, layout, images, part)
            recs = ds.make_record(
                W.OP_BACKUP_WRITE, jnp.asarray(e["key_lo"]),
                jnp.asarray(e["key_hi"]), aux=jnp.asarray(e["version"]),
                value=jnp.asarray(e["value"]))
            live = jnp.asarray(e["clean"])
        else:
            base = (layout["leaves"].base if part == src
                    else layout["bleaves"].base)
            offs = jnp.asarray([base + i * cfg.leaf_words
                                for i in range(cfg.n_leaves)], jnp.uint32)
            images, s = _read_region_images(t, state, layout, src, dst, offs,
                                            cfg.leaf_words, nic=nic)
            total = total + s
            p = jax.device_get(ds.parse_leaf(cfg, jnp.asarray(images)))
            lo, hi = (int(np.asarray(x)) for x in
                      ds.partition_bounds(cfg, part))
            stable = (p["version"] % 2 == 0) & (p["lock"] == 0)
            sel = (p["live"] & stable[:, None]
                   & (p["keys"] >= lo) & (p["keys"] <= hi))
            keys = p["keys"].reshape(-1)
            vals = p["values"].reshape(-1, sl.VALUE_WORDS)
            recs = ds.make_record(W.OP_BT_BACKUP, jnp.asarray(keys),
                                  jnp.zeros_like(jnp.asarray(keys)),
                                  value=jnp.asarray(vals))
            live = jnp.asarray(sel.reshape(-1))
        B = recs.shape[0]
        n_local = t.n_local
        dest = jnp.full((n_local, B), dst, jnp.int32)
        recs_b = jnp.broadcast_to(recs[None], (n_local, B, recs.shape[-1]))
        en = (t.node_ids() == dst)[:, None] & live[None, :]
        state, _, _, s2 = R.rpc_call(t, state, dest, recs_b, handler,
                                     enabled=en, nic=nic)
        total = total + s2
    return state, total


def migrate_partition(t: Transport, state, cfg, layout,
                      pcfg: PlacementConfig, table: PlacementTable,
                      part: int, dst: int, *, nic=None):
    """Transactionally move partition ``part`` to new owner ``dst``
    (source-lock -> copy -> epoch flip), riding the OCC machinery:

      1. ENUMERATE  — one-sided sweep of the source's slot/leaf region.
      2. SOURCE-LOCK — OP_LOCK / OP_BT_LOCK every record/leaf that carries
         the partition's keys, with the migration tag.  Any in-flight client
         transaction holds one of those locks, so the migration's lock fails
         and the whole migration ABORTS (unlock, table unchanged) — that is
         the no-lost-write guarantee: a migration never races a commit.
      3. FREEZE     — install the bumped table on the SOURCE only: it stops
         granting NEW lock-class ops for the partition (ST_WRONG_EPOCH),
         while reads and in-flight unlocks still work.
      4. COPY       — re-read the (now lock-stable) records and install them
         on ``dst`` via the backup classes.
      5. FLIP       — install the bumped table everywhere; clients that still
         route with the old table get ST_WRONG_EPOCH and refresh.
      6. UNLOCK     — release the migration locks at the source (abort-class,
         installs nothing).

    The new copy row is [dst] + old copies (minus dst), truncated to f+1 —
    the old owner stays on as a backup when f >= 1, so it keeps receiving
    the commit fan-out and stale-table reads against it stay consistent.

    Returns (table', state, WireStats, migrated: bool) — table' is the input
    table when the migration aborted (retry after the blocking transactions
    drain)."""
    ds, kind = _ds_for(cfg)
    handler = ds.make_rpc_handler(cfg, layout)
    part, dst = int(part), int(dst)
    src = int(np.asarray(table.copies)[part, 0])
    total = WireStats.zero()
    if src == dst:
        return table, state, total, True
    n_local = t.n_local

    old_row = [int(c) for c in np.asarray(table.copies)[part] if c >= 0]
    new_row = ([dst] + [c for c in old_row if c != dst])[:pcfg.n_copies]
    new_row += [-1] * (np.asarray(table.copies).shape[1] - len(new_row))
    table2 = PlacementTable(table.epoch + 1,
                            table.copies.at[part].set(
                                jnp.asarray(new_row, jnp.int32)),
                            table.alive)

    def src_rpc(recs, live):
        nonlocal state, total
        B = recs.shape[0]
        dd = jnp.full((n_local, B), src, jnp.int32)
        rb = jnp.broadcast_to(recs[None], (n_local, B, recs.shape[-1]))
        en = (t.node_ids() == dst)[:, None] & live[None, :]
        state, rep, _, s = R.rpc_call(t, state, dd, rb, handler, enabled=en,
                                      nic=nic)
        total = total + s
        return np.asarray(jax.device_get(rep[dst]))

    # -- 1. enumerate ------------------------------------------------------
    if kind == "hash":
        offs = jnp.asarray([int(ds.slot_idx_offset(layout, jnp.uint32(i)))
                            for i in range(cfg.n_slots)], jnp.uint32)
        words = sl.SLOT_WORDS
    else:
        base = (layout["leaves"].base if part == src
                else layout["bleaves"].base)
        offs = jnp.asarray([base + i * cfg.leaf_words
                            for i in range(cfg.n_leaves)], jnp.uint32)
        words = cfg.leaf_words
    images, s = _read_region_images(t, state, layout, src, dst, offs, words,
                                    nic=nic)
    total = total + s

    # -- 2. source-lock ----------------------------------------------------
    tag = np.uint32(MIG_TAG | part)
    if kind == "hash":
        e = _enumerate_hash(cfg, layout, images, part)
        sel = e["sel"]                     # every in-partition record,
        lock_recs = ds.make_record(        # locked/mid-commit ones included:
            W.OP_LOCK, jnp.asarray(e["key_lo"]),      # they DETECT conflicts
            jnp.asarray(e["key_hi"]), aux=jnp.full((len(sel),), tag))
        lock_keys = (e["key_lo"], e["key_hi"])
    else:
        p = jax.device_get(ds.parse_leaf(cfg, jnp.asarray(images)))
        lo, hi = (int(np.asarray(x)) for x in ds.partition_bounds(cfg, part))
        in_rng = p["live"] & (p["keys"] >= lo) & (p["keys"] <= hi)
        sel = in_rng.any(axis=1)           # leaves carrying partition keys
        first = np.where(in_rng, p["keys"],
                         np.uint32(0xFFFFFFFF)).min(axis=1)
        lock_recs = ds.make_record(W.OP_BT_LOCK, jnp.asarray(first),
                                   jnp.zeros((len(sel),), jnp.uint32),
                                   aux=jnp.full((len(sel),), tag))
        lock_keys = (first, np.zeros_like(first))
    rep = src_rpc(lock_recs, jnp.asarray(sel))
    got = sel & (rep[:, 0] == W.ST_OK)
    lock_aux = rep[:, 1]                   # slot/header idx for the unlock

    def unlock():
        if kind == "hash":
            recs = ds.make_record(W.OP_ABORT_UNLOCK,
                                  jnp.full((len(got),), tag),
                                  jnp.zeros((len(got),), jnp.uint32),
                                  aux=jnp.asarray(lock_aux))
        else:
            recs = ds.make_record(W.OP_BT_ABORT, jnp.asarray(lock_keys[0]),
                                  jnp.full((len(got),), tag),
                                  aux=jnp.asarray(lock_aux))
        src_rpc(recs, jnp.asarray(got))

    if bool((sel & ~got).any()):
        # an in-flight transaction holds part of the partition: abort
        unlock()
        return table, state, total, False

    # -- 3. freeze (source learns the new epoch first) ---------------------
    state, s = install_table(t, state, layout, pcfg, table2, handler,
                             targets=[src], issuer=dst, nic=nic)
    total = total + s

    # -- 4. copy (records are lock-stable now) -----------------------------
    images, s = _read_region_images(t, state, layout, src, dst, offs, words,
                                    nic=nic)
    total = total + s
    B = offs.shape[0]
    if kind == "hash":
        e = _enumerate_hash(cfg, layout, images, part)
        recs = ds.make_record(W.OP_BACKUP_WRITE, jnp.asarray(e["key_lo"]),
                              jnp.asarray(e["key_hi"]),
                              aux=jnp.asarray(e["version"]),
                              value=jnp.asarray(e["value"]))
        live = jnp.asarray(e["sel"] & (e["version"] % 2 == 0))
    else:
        p = jax.device_get(ds.parse_leaf(cfg, jnp.asarray(images)))
        in_rng = p["live"] & (p["keys"] >= lo) & (p["keys"] <= hi)
        keys = p["keys"].reshape(-1)
        vals = p["values"].reshape(-1, sl.VALUE_WORDS)
        recs = ds.make_record(W.OP_BT_BACKUP, jnp.asarray(keys),
                              jnp.zeros_like(jnp.asarray(keys)),
                              value=jnp.asarray(vals))
        live = jnp.asarray(in_rng.reshape(-1))
    Bc = recs.shape[0]
    dd = jnp.full((n_local, Bc), dst, jnp.int32)
    rb_ = jnp.broadcast_to(recs[None], (n_local, Bc, recs.shape[-1]))
    en = (t.node_ids() == dst)[:, None] & live[None, :]
    state, _, _, s = R.rpc_call(t, state, dd, rb_, handler, enabled=en,
                                nic=nic)
    total = total + s

    # -- 5. flip everywhere -------------------------------------------------
    state, s = install_table(t, state, layout, pcfg, table2, handler,
                             issuer=dst, nic=nic)
    total = total + s

    # -- 6. unlock the source ----------------------------------------------
    unlock()
    return table2, state, total, True


# ---------------------------------------------------------------------------
# Read fail-over (generic over the data-structure interface)
# ---------------------------------------------------------------------------
def failover_lookup(t: Transport, state, cfg, layout, table: PlacementTable,
                    key_lo, key_hi, *, ds=None,
                    capacity: Optional[int] = None, enabled=None, nic=None):
    """Point reads routed to each key's first LIVE copy: the one-two-sided
    hybrid probe + RPC fallback, with the destination resolved through the
    placement table (the ONE failover rule) instead of hash-only ring math —
    this is what serves both the hash table and the btree's backup tree
    after a primary dies.  Returns dict(found, value, version, node,
    slot_idx, overflow, dead_route, wire)."""
    if ds is None:
        from repro.core.datastructs import hashtable as ht
        ds = ht
    if enabled is None:
        enabled = jnp.ones(jnp.shape(key_lo), bool)
    part = ds.part_of(cfg, key_lo, key_hi)
    dest, reachable = live_dest(table, part)
    en = enabled & reachable
    _, off, hit = ds.lookup_start(cfg, layout, key_lo, key_hi, None)

    buf, ovf1, s1 = osd.remote_read(
        t, state["arena"], dest, off, length=ds.probe_words(cfg),
        capacity=capacity, enabled=en, nic=nic)
    pe = ds.probe_end(cfg, layout, buf, key_lo, key_hi, off, hit)
    success = pe["found"] & ~ovf1 & en
    resolved = pe["resolved"] & ~ovf1 & en

    # RPC fallback at the SAME live copy (chained / stale-routed / torn lanes)
    need = en & ~resolved
    _, rep2, ovf2, s2 = R.rpc_call(
        t, state, dest, ds.lookup_records(cfg, key_lo, key_hi),
        ds.make_lookup_handler_vector(cfg, layout), capacity=capacity,
        enabled=need, nic=nic)
    rpc_ok = need & (rep2[..., 0] == W.ST_OK) & ~ovf2
    value = jnp.where(rpc_ok[..., None], rep2[..., 3:], pe["value"])
    version = jnp.where(rpc_ok, rep2[..., 2], pe["version"])
    slot_idx = jnp.where(rpc_ok, rep2[..., 1], pe["slot_idx"])

    return dict(
        found=success | rpc_ok,
        value=value,
        version=version,
        node=dest,
        slot_idx=slot_idx,
        overflow=need & ovf2,
        dead_route=enabled & ~reachable,
        wire=s1 + s2,
    )
