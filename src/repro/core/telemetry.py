"""Flight-recorder telemetry for the dataplane (observability layer).

Storm's authors diagnosed RDMA scalability by *watching counters* — NIC cache
hit rates, per-op round trips, abort causes — evolve over a run.  This module
is the repo's equivalent: a scan-safe flight recorder that can be threaded
through every exchange round without perturbing the protocol.

Three pieces:

  * :class:`TraceBuffer` + :class:`Recorder` — a fixed-capacity buffer of
    fixed-width DEVICE-SIDE event rows, appended inside the ``lax.scan``
    bodies of ``txloop.tx_loop`` / ``txloop.scan_loop`` and inside
    ``roundsched.fused_round``.  One row per fused exchange round (round
    index, phase tag, class count, WireStats snapshot incl. the modeled NIC
    hit-rate terms, per-destination message/byte counts) plus one SUMMARY row
    per protocol round (committed / attempts / abort-cause vector).  All
    shapes are static and every append is pure array arithmetic, so recording
    is legal anywhere in a traced computation.

  * a modeled per-lane LATENCY accumulator: each protocol round's recorded
    events are priced with the same constants the benchmarks' ``ModelFabric``
    uses (one-sided vs RPC base round trip, link serialization of the round's
    bytes, the ``nic.ConnTable`` per-op connection-state penalty), and every
    lane still live in that round accumulates the cost.  The result is a
    latency *sample per lane* — histograms (p50/p90/p99 per abort-retry
    path), not means.

  * export layers: :func:`export_trace` renders the buffer as Chrome/Perfetto
    trace-event JSON (one track per destination node, one slice per
    round x class, counter tracks for aborts), and :class:`MetricsRegistry`
    collects named host-side counters into a flat ``metrics.json``.

The discipline every other optional subsystem follows (``nic=``, ``rep=``,
``ptable=``) applies here too: ``telemetry=None`` (the default everywhere) is
BIT-IDENTICAL and round-identical to a build without this module — recording
only ever *reads* protocol values (tests/test_telemetry.py asserts this; the
bench gate pins the round-trip schedule).

The threading idiom is a MUTABLE HOLDER, not a return value: a
:class:`Recorder` passed down the call tree accumulates the traced
:class:`TraceBuffer` value by assignment during tracing (jax's ``named_call``
is a pure name-scope here, so no trace boundary is crossed), and the loop
body that created it threads ``recorder.buf`` back into its scan carry.  That
keeps every dataplane function's return signature unchanged.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transport import WireStats

# ---------------------------------------------------------------------------
# Phase tags.  An event's phase names the protocol work its exchange round
# carried and decides its latency pricing: READ / VALIDATE / REFRESH rounds
# are one-sided (rt_onesided_us), FALLBACK / LOCK / COMMIT rounds run RPC
# handlers (rt_rpc_us).  SUMMARY rows carry the per-protocol-round abort
# vector and are not priced.
# ---------------------------------------------------------------------------
PH_OTHER = 0      # unclassified single rounds (direct rpc_call/remote_read)
PH_READ = 1       # one-sided read-set probe (hybrid phase 2)
PH_FALLBACK = 2   # read-set RPC fallback in its own round (unfused schedule)
PH_LOCK = 3       # LOCK round; under the fused schedule this single
                  # exchange also carries the fallback + validate classes
PH_VALIDATE = 4   # one-sided validate re-read
PH_COMMIT = 5     # COMMIT/ABORT round (+ backup fan-out classes at f > 0)
PH_REFRESH = 6    # metadata refresh (placement table / separator directory)
PH_SUMMARY = 7    # per-protocol-round summary (abort-cause vector)

PHASE_NAMES = {
    PH_OTHER: "other", PH_READ: "read", PH_FALLBACK: "fallback",
    PH_LOCK: "lock", PH_VALIDATE: "validate", PH_COMMIT: "commit",
    PH_REFRESH: "refresh", PH_SUMMARY: "summary",
}
# phases whose exchange is one-sided (priced at rt_onesided_us)
_ONESIDED_PHASES = (PH_READ, PH_VALIDATE, PH_REFRESH)

# ---------------------------------------------------------------------------
# Event-row schema.  A row is (EV_WORDS + 2 * n_dst) float32: the fixed
# columns below, then per-destination message counts, then per-destination
# byte counts (both directions, coalesced wire accounting — summing either
# tail over destinations reproduces the scalar WireStats of the round).
# ---------------------------------------------------------------------------
EV_ROUND = 0          # protocol round index (txloop's scan counter)
EV_PHASE = 1          # phase tag above
EV_CLASSES = 2        # traffic classes fused into this exchange round
EV_RT = 3             # round trips (0 for an empty / fully-parked round)
EV_MSGS = 4           # coalesced wire messages (both directions)
EV_OPS = 5            # delivered application-level requests
EV_REQ_BYTES = 6
EV_REPLY_BYTES = 7
EV_NIC_HIT_OPS = 8    # ops-weighted modeled NIC-cache hits (snapshot)
EV_NIC_PENALTY = 9    # ops-weighted modeled connection-state penalty (us)
EV_COMMITTED = 10     # SUMMARY rows only: lanes committed this round ...
EV_ATTEMPTS = 11      # ... lanes live entering the round,
EV_AB_LOCK = 12       # and the abort-cause vector
EV_AB_VALIDATE = 13
EV_AB_OVERFLOW = 14
EV_AB_STALE = 15
EV_WORDS = 16         # fixed columns; per-dest tails follow


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static (trace-time) flight-recorder configuration.

    capacity: event rows in the buffer (None = sized by the loop from its
              ``max_rounds``); a full buffer drops further events and counts
              them in ``TraceBuffer.dropped`` — never an error, never a
              dynamic shape.
    rt_onesided_us / rt_rpc_us / link_gbps: the latency-pricing constants,
              defaulting to the benchmarks' ``ModelFabric`` fabric.
    """
    capacity: Optional[int] = None
    rt_onesided_us: float = 1.8
    rt_rpc_us: float = 2.7
    link_gbps: float = 100.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TraceBuffer:
    """Fixed-width device-side event log (a pytree; scan-carry friendly)."""
    rows: jnp.ndarray      # (capacity, EV_WORDS + 2 * n_dst) float32
    n: jnp.ndarray         # () int32 — rows written
    rnd: jnp.ndarray       # () int32 — current protocol round register
    dropped: jnp.ndarray   # () int32 — events dropped at capacity

    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    @property
    def n_dst(self) -> int:
        return (self.rows.shape[1] - EV_WORDS) // 2


def make_buffer(n_dst: int, capacity: int) -> TraceBuffer:
    """Fresh empty buffer with per-destination tails for ``n_dst`` nodes."""
    return TraceBuffer(
        rows=jnp.zeros((capacity, EV_WORDS + 2 * n_dst), jnp.float32),
        n=jnp.zeros((), jnp.int32),
        rnd=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32))


class Recorder:
    """Mutable holder threading a :class:`TraceBuffer` through a call tree.

    Dataplane functions take ``telemetry: Recorder | None = None`` and call
    :meth:`record` — the holder swaps in the new traced buffer value, so no
    return signature changes.  The creating loop body reads ``.buf`` back
    into its scan carry after the call tree returns.  Valid within one trace
    scope (a single ``lax.scan`` body iteration), which is exactly where the
    loops construct it.
    """

    __slots__ = ("config", "buf")

    def __init__(self, config: TelemetryConfig, buf: TraceBuffer):
        self.config = config
        self.buf = buf

    # -- appends ------------------------------------------------------------
    def set_round(self, rnd):
        """Stamp the protocol round index subsequent events belong to."""
        self.buf = dataclasses.replace(
            self.buf, rnd=jnp.asarray(rnd, jnp.int32))

    def _append(self, fixed, per_dest_msgs=None, per_dest_bytes=None):
        b = self.buf
        n_dst = b.n_dst
        zero_d = jnp.zeros((n_dst,), jnp.float32)
        pd_m = zero_d if per_dest_msgs is None else per_dest_msgs.astype(
            jnp.float32)
        pd_b = zero_d if per_dest_bytes is None else per_dest_bytes.astype(
            jnp.float32)
        row = jnp.concatenate([jnp.stack(fixed).astype(jnp.float32),
                               pd_m, pd_b])
        ok = b.n < b.capacity
        idx = jnp.minimum(b.n, b.capacity - 1)
        rows = b.rows.at[idx].set(jnp.where(ok, row, b.rows[idx]))
        self.buf = TraceBuffer(
            rows=rows,
            n=b.n + ok.astype(jnp.int32),
            rnd=b.rnd,
            dropped=b.dropped + (~ok).astype(jnp.int32))

    def record(self, phase: int, stats: WireStats, *, n_classes: int = 1,
               per_dest_msgs=None, per_dest_bytes=None):
        """Append one exchange-round event (called by fused_round)."""
        f32 = lambda x: jnp.asarray(x, jnp.float32)
        z = jnp.zeros((), jnp.float32)
        self._append(
            [f32(self.buf.rnd), f32(phase), f32(n_classes),
             f32(stats.round_trips), f32(stats.messages), f32(stats.ops),
             f32(stats.req_bytes), f32(stats.reply_bytes),
             f32(stats.nic_hit_ops), f32(stats.nic_penalty_us),
             z, z, z, z, z, z],
            per_dest_msgs=per_dest_msgs, per_dest_bytes=per_dest_bytes)

    def summary(self, *, committed, attempts, abort_lock, abort_validate,
                abort_overflow, abort_stale):
        """Append one per-protocol-round SUMMARY row (abort-cause vector)."""
        f32 = lambda x: jnp.asarray(x, jnp.float32)
        z = jnp.zeros((), jnp.float32)
        self._append(
            [f32(self.buf.rnd), f32(PH_SUMMARY), z, z, z, z, z, z, z, z,
             f32(committed), f32(attempts), f32(abort_lock),
             f32(abort_validate), f32(abort_overflow), f32(abort_stale)])

    # -- modeled latency ----------------------------------------------------
    def round_cost_us(self, n0):
        """Modeled latency (us) of the events appended since row ``n0``.

        Per event: a base round trip when the round actually went on the wire
        (one-sided vs RPC by phase tag), plus link serialization of the
        round's coalesced bytes, plus the modeled per-op connection-state
        penalty — the per-round analogue of ``ModelFabric``'s pricing.
        SUMMARY rows cost nothing (rt = 0, bytes = 0).
        """
        cfg = self.config
        b = self.buf
        idx = jnp.arange(b.capacity)
        win = (idx >= n0) & (idx < b.n)
        phase = b.rows[:, EV_PHASE]
        onesided = jnp.zeros((b.capacity,), bool)
        for p in _ONESIDED_PHASES:
            onesided = onesided | (phase == p)
        base = jnp.where(onesided, cfg.rt_onesided_us, cfg.rt_rpc_us)
        live = b.rows[:, EV_RT] > 0
        ops = jnp.maximum(b.rows[:, EV_OPS], 1.0)
        penalty = b.rows[:, EV_NIC_PENALTY] / ops
        byts = b.rows[:, EV_REQ_BYTES] + b.rows[:, EV_REPLY_BYTES]
        ser = byts * 8.0e-3 / cfg.link_gbps
        cost = jnp.where(win & live, base + penalty, 0.0) + \
            jnp.where(win, ser, 0.0)
        return jnp.sum(cost)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TelemetryOut:
    """What a loop returns when ``telemetry=`` is enabled."""
    trace: TraceBuffer
    lane_latency_us: jnp.ndarray   # (N, B) f32 — modeled latency to commit
    #                                (or to the final abort) of every lane


def loop_capacity(tel: TelemetryConfig, max_rounds: int) -> int:
    """Buffer capacity for a retry loop: the worst round appends <= 9 events
    (two refreshes, five phase rounds on the unfused schedule, summary)."""
    if tel.capacity is not None:
        return int(tel.capacity)
    return max_rounds * 10 + 4


# ---------------------------------------------------------------------------
# Host-side views + percentile summaries
# ---------------------------------------------------------------------------
def events(buf: TraceBuffer) -> np.ndarray:
    """The written rows as a host array (n, EV_WORDS + 2 * n_dst)."""
    return np.asarray(buf.rows)[: int(buf.n)]


def summarize(latencies) -> dict:
    """Percentile summary of a latency sample: {p50, p90, p99, mean} floats.

    THE percentile helper — benchmarks re-export it from
    ``benchmarks/common.py``; report distributions with it, never bare means.
    Empty samples summarize to NaNs (callers usually skip those groups).
    """
    a = np.asarray(latencies, np.float64).ravel()
    if a.size == 0:
        nan = float("nan")
        return dict(p50=nan, p90=nan, p99=nan, mean=nan)
    return dict(p50=float(np.percentile(a, 50)),
                p90=float(np.percentile(a, 90)),
                p99=float(np.percentile(a, 99)),
                mean=float(a.mean()))


def latency_by_path(lane_latency_us, committed, commit_round) -> dict:
    """Latency histograms per abort-retry path.

    Groups the per-lane modeled latency sample by outcome: committed lanes by
    the round they committed in (``retry0`` = first attempt, ``retryK`` =
    K-th re-execution), plus ``committed`` (all of them) and ``aborted``
    (lanes that never committed — their latency is time burned to the final
    abort).  Returns {group: summarize(...)} with empty groups omitted.
    """
    lat = np.asarray(lane_latency_us, np.float64).ravel()
    com = np.asarray(committed, bool).ravel()
    cr = np.asarray(commit_round, np.int64).ravel()
    out = {}
    if com.any():
        out["committed"] = summarize(lat[com])
    if (~com).any():
        out["aborted"] = summarize(lat[~com])
    for k in sorted({int(k) for k in cr[com]}):
        out[f"retry{k}"] = summarize(lat[com & (cr == k)])
    return out


# ---------------------------------------------------------------------------
# MetricsRegistry — named host-side counters -> flat metrics.json
# ---------------------------------------------------------------------------
class MetricsRegistry:
    """Named counters the benchmarks publish to ``metrics.json``.

    Plain host-side floats (increments happen after a traced computation
    returns, from its results) — the device-side complement is the
    TraceBuffer.  ``observe`` stores a whole latency distribution under
    dotted percentile keys, so the gate can pin p50/p99 by name.
    """

    def __init__(self):
        self._vals: dict = {}

    def incr(self, name: str, value=1.0):
        self._vals[name] = float(self._vals.get(name, 0.0)) + float(value)

    def set(self, name: str, value):
        self._vals[name] = float(value)

    def observe(self, name: str, latencies):
        for k, v in summarize(latencies).items():
            self._vals[f"{name}.{k}"] = v

    def get(self, name: str, default=0.0) -> float:
        return float(self._vals.get(name, default))

    def as_dict(self) -> dict:
        return dict(sorted(self._vals.items()))

    def write(self, path: str):
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=2, sort_keys=True)
            f.write("\n")


# ---------------------------------------------------------------------------
# Chrome/Perfetto trace-event export
# ---------------------------------------------------------------------------
def export_trace(buf: TraceBuffer, *, config: TelemetryConfig = None,
                 path: Optional[str] = None, label: str = "storm") -> dict:
    """Render the flight recorder as Chrome trace-event JSON.

    Layout: one PROCESS (track group) per destination node; within it, one
    slice per round x phase carrying that node's share of the round's
    messages/bytes in its args; a synthetic ``cluster`` process carries the
    per-round abort-cause counter tracks.  Timestamps are the MODELED
    timeline: events are laid end-to-end at their priced durations, so slice
    width in the UI is modeled round latency.  Loads directly in
    https://ui.perfetto.dev ("Open trace file") or chrome://tracing.
    """
    cfg = config or TelemetryConfig()
    ev = events(buf)
    n_dst = buf.n_dst
    out = []
    for d in range(n_dst):
        out.append(dict(ph="M", name="process_name", pid=d,
                        args=dict(name=f"node {d}")))
    cluster_pid = n_dst
    out.append(dict(ph="M", name="process_name", pid=cluster_pid,
                    args=dict(name=f"{label} cluster")))
    t_us = 0.0
    for row in ev:
        phase = int(row[EV_PHASE])
        rnd = int(row[EV_ROUND])
        pname = PHASE_NAMES.get(phase, str(phase))
        if phase == PH_SUMMARY:
            out.append(dict(ph="C", name="aborts", pid=cluster_pid,
                            ts=t_us, args=dict(
                                lock=float(row[EV_AB_LOCK]),
                                validate=float(row[EV_AB_VALIDATE]),
                                overflow=float(row[EV_AB_OVERFLOW]),
                                stale=float(row[EV_AB_STALE]))))
            out.append(dict(ph="C", name="progress", pid=cluster_pid,
                            ts=t_us, args=dict(
                                committed=float(row[EV_COMMITTED]),
                                attempts=float(row[EV_ATTEMPTS]))))
            continue
        base = (cfg.rt_onesided_us if phase in _ONESIDED_PHASES
                else cfg.rt_rpc_us)
        live = bool(row[EV_RT] > 0)
        penalty = float(row[EV_NIC_PENALTY]) / max(float(row[EV_OPS]), 1.0)
        ser = float(row[EV_REQ_BYTES] + row[EV_REPLY_BYTES]) * 8.0e-3 / \
            cfg.link_gbps
        dur = (base + penalty if live else 0.0) + ser
        name = f"r{rnd}/{pname}"
        hit_rate = (row[EV_NIC_HIT_OPS] / row[EV_OPS]
                    if row[EV_OPS] > 0 else 1.0)
        for d in range(n_dst):
            msgs = row[EV_WORDS + d]
            byts = row[EV_WORDS + n_dst + d]
            if msgs <= 0 and not live:
                continue
            out.append(dict(
                ph="X", name=name, cat=pname, pid=d, tid=phase,
                ts=t_us, dur=max(dur, 0.001), args=dict(
                    round=rnd, classes=int(row[EV_CLASSES]),
                    msgs=float(msgs), bytes=float(byts),
                    ops=float(row[EV_OPS]),
                    nic_hit_rate=float(hit_rate))))
        t_us += dur
    doc = dict(traceEvents=out, displayTimeUnit="ms",
               otherData=dict(
                   source=label, n_nodes=n_dst,
                   events=int(buf.n), dropped=int(buf.dropped),
                   modeled_span_us=t_us))
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
    return doc
