"""Contiguous memory regions (Storm §4.3, §5.1) and the paged/physical-segment
addressing modes (§6.2.5).

Storm's principle: register FEW, LARGE, CONTIGUOUS regions so the NIC's MPT
stays tiny, and use *physical segments* so the MTT disappears entirely.  The
TPU/XLA analogue: every node owns ONE arena buffer (a flat uint32 array) out
of which all data structures are carved at static offsets.  One buffer means
one allocation, static addressing, donation-friendly update-in-place, and no
per-object buffer zoo in the HLO — the compiler-level equivalent of a single
MPT entry.

Two addressing modes are implemented so the paper's physical-segment
experiment can be reproduced:

  * ``flat``  — "physical segment": address = offset.  One bounds check.
  * ``paged`` — "4KB pages": every access walks a page table (the MTT):
                phys = page_table[offset // page] * page + offset % page.
                This models the extra dependent load RDMA NICs pay per
                translation; on TPU it shows up as an extra gather per access.

`RegionTable` is the MPT: (region_id -> base, size).  Storm keeps it minimal —
so do we: a handful of regions per node (hash buckets, overflow pool,
allocator state, RPC rings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp

from repro.core import slots as sl


@dataclasses.dataclass(frozen=True)
class Region:
    region_id: int
    base: int          # word offset in the arena
    size: int          # words

    @property
    def end(self) -> int:
        return self.base + self.size


@dataclasses.dataclass
class RegionTable:
    """The MPT analogue. Registration happens at setup time (off the data
    path, like Storm's kernel-mediated physical-segment registration)."""
    regions: Dict[str, Region] = dataclasses.field(default_factory=dict)
    next_base: int = 0
    next_id: int = 0

    def register(self, name: str, size_words: int) -> Region:
        if name in self.regions:
            raise ValueError(f"region {name!r} already registered")
        r = Region(self.next_id, self.next_base, size_words)
        self.regions[name] = r
        self.next_base += size_words
        self.next_id += 1
        return r

    @property
    def total_words(self) -> int:
        return self.next_base

    def __getitem__(self, name: str) -> Region:
        return self.regions[name]


def make_arena(table: RegionTable, dtype=jnp.uint32) -> jnp.ndarray:
    """One contiguous arena per node — the Storm allocator's big chunk."""
    return jnp.zeros((table.total_words,), dtype)


# ---------------------------------------------------------------------------
# Addressing modes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AddressMode:
    """flat = physical segment; paged = per-page translation (MTT walk)."""
    kind: str = "flat"            # "flat" | "paged"
    page_words: int = 1024        # 4 KiB pages in uint32 words

    def make_page_table(self, total_words: int, key=None) -> jnp.ndarray | None:
        if self.kind == "flat":
            return None
        n_pages = -(-total_words // self.page_words)
        # Identity mapping by default; tests may permute it to prove the
        # translation is actually honoured.
        return jnp.arange(n_pages, dtype=jnp.uint32)

    def translate(self, page_table, offsets):
        """offsets: uint32 word offsets -> physical word offsets."""
        if self.kind == "flat":
            return offsets
        page = offsets // self.page_words
        within = offsets % self.page_words
        phys_page = page_table[page]
        return phys_page * self.page_words + within


def in_region(region: Region, offsets, length: int = 1):
    """True where the whole access [offset, offset + length) lies inside
    `region` — the NIC's MPT bounds check.  offsets: (...,) -> (...,) bool.

    The bound is computed in Python (static) and compared without any
    arithmetic on the traced offsets, so a huge offset can never wrap uint32
    addition and sneak past the check."""
    off = jnp.asarray(offsets, jnp.uint32)
    if length > region.size:
        return jnp.zeros(off.shape, bool)
    return (off >= jnp.uint32(region.base)) & (off <= jnp.uint32(region.end - length))


def arena_read(arena, offsets, length: int, mode: AddressMode | None = None,
               page_table=None, region: Region | None = None):
    """Gather `length` consecutive words starting at each offset.

    This is the owner-side data movement of a one-sided READ: pure gather,
    no application logic.  offsets: (...,) uint32 -> (..., length).

    region: optional bounds check (the MPT's protection role) — lanes whose
    access falls outside the region are REJECTED and read back zeros, in both
    addressing modes, instead of leaking adjacent regions' words.
    """
    idx = offsets[..., None].astype(jnp.uint32) + jnp.arange(length, dtype=jnp.uint32)
    if mode is not None and mode.kind == "paged":
        idx = mode.translate(page_table, idx)
    out = arena[idx]
    if region is not None:
        ok = in_region(region, offsets, length)
        out = jnp.where(ok[..., None], out, jnp.zeros_like(out))
    return out


def arena_write(arena, offsets, values, mode: AddressMode | None = None,
                page_table=None, enabled=None, region: Region | None = None):
    """Scatter consecutive words at each offset (one-sided WRITE).

    values: (..., L); enabled: optional (...,) bool mask (lanes whose write is
    suppressed — needed for the masked RPC fallback lanes).
    region: optional bounds check — out-of-region writes are rejected (the
    arena is untouched), in both addressing modes.
    """
    length = values.shape[-1]
    if region is not None:
        ok = in_region(region, offsets, length)
        enabled = ok if enabled is None else (enabled & ok)
    idx = offsets[..., None].astype(jnp.uint32) + jnp.arange(length, dtype=jnp.uint32)
    if mode is not None and mode.kind == "paged":
        idx = mode.translate(page_table, idx)
    flat_idx = idx.reshape(-1)
    flat_val = values.reshape(-1).astype(arena.dtype)
    if enabled is not None:
        keep = jnp.broadcast_to(enabled[..., None], idx.shape).reshape(-1)
        # Redirect suppressed lanes to a scratch word (last word of arena is
        # reserved as the write sink by every layout built in this module).
        flat_idx = jnp.where(keep, flat_idx, jnp.uint32(arena.shape[0] - 1))
        cur = arena[flat_idx]
        flat_val = jnp.where(keep, flat_val, cur)
    return arena.at[flat_idx].set(flat_val, mode="drop")


def slot_offset(region: Region, slot_idx):
    """Word offset of slot `slot_idx` inside a slot-array region."""
    return jnp.uint32(region.base) + jnp.asarray(slot_idx, jnp.uint32) * jnp.uint32(sl.SLOT_WORDS)
