# Storm's primary contribution as a composable JAX module: a transactional
# dataplane for remote (sharded) data structures.
#   slots      — MICA-style 128B inline slot codec (key|version|lock|value)
#   regions    — contiguous arenas + flat/paged addressing (physical segments)
#   nic        — connection-state model: QP modes (RC-exclusive / RC-shared /
#                DCT) + NIC-cache hit model (Fig. 7, single source of truth)
#   transport  — RC-fabric analogue: dest-major exchange on sim or mesh
#   onesided   — one-sided READ/WRITE (owner does address translation only)
#   roundsched — multi-class fused round scheduler (doorbell batching: many
#                traffic classes, ONE all-to-all each way)
#   rpc        — write-based RPC: inbox + single completion mask + handlers
#   hybrid     — one-two-sided operations (Algorithm 1)
#   tx         — OCC transactions (execute/lock/validate/commit, Fig. 3) on a
#                fused 3-4-round schedule (5-round per-phase reference kept)
#   txloop     — bounded-retry transaction engine (re-enable masks + backoff)
#   cost_model — the bytes/round-trip napkin math behind every hybrid choice
from repro.core import (cost_model, hybrid, nic, onesided, regions,  # noqa: F401
                        roundsched, rpc, slots, transport, tx, txloop)
