"""One-two-sided hybrid operations (Storm §4.4, Algorithm 1).

    1. lookup_start  -> where might the item be? (client-side metadata/cache)
    2. remote_read   -> ONE-SIDED fine-grained read of that location
    3. lookup_end    -> did we get it? (key/version/lock validation)
    4. if not        -> WRITE-BASED RPC; the owner chases the pointers
    5. lookup_end    -> cache the learned address for next time

All lanes move through the phases together (SPMD); the RPC phase is issued
with a per-lane `enabled` mask so only failed lanes consume handler work and
wire bytes — the batched analogue of "switch to RPC for this operation".

Modes reproduce the paper's configurations:
  * use_onesided=False           -> "Storm" (RPC-only baseline in Fig. 4)
  * use_onesided=True            -> "Storm(oversub)" one-two-sided
  * use_onesided=True + cache    -> toward "Storm(perfect)" (address caching)

Public API: ``hybrid_lookup`` (the whole Algorithm 1), and its split halves
``onesided_probe`` / ``merge_rpc_fallback`` / ``update_lookup_cache`` (used
by tx's fused schedule to ride the RPC fallback on the LOCK round), plus
``HybridMetrics``.  Invariant: a lookup dropped by send-queue back-pressure
reports ``overflow`` — found=False then means "not delivered", never "key
absent", and transactional callers must abort-and-retry it.

The probe is DATA-STRUCTURE-GENERIC (Storm Table 3): every entry point takes
``ds=`` — a datastructs module exporting ``lookup_start`` / ``probe_end`` /
``probe_words`` / ``lookup_records`` / ``uses_probe_cache`` / ``cache_update``
and the handler constructors — defaulting to the hash table.  The ordered
B-link index (``datastructs.btree``) plugs in the same way; its ``probe_end``
additionally distinguishes *resolved* from *found*: a stable in-fence leaf
that lacks the key is a definitive miss needing NO RPC fallback, whereas a
hash-table miss might still hide on an unread overflow chain.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import onesided as osd
from repro.core import rpc as R
from repro.core import telemetry as T
from repro.core import wireproto as W
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport, WireStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridMetrics:
    onesided_success: jnp.ndarray   # lanes satisfied by the one-sided read
    rpc_fallback: jnp.ndarray       # lanes that needed the RPC
    total: jnp.ndarray
    wire: WireStats

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.float32)
        return HybridMetrics(z, z, z, WireStats.zero())


def onesided_probe(t: Transport, state, key_lo, key_hi, cfg, layout, *,
                   cache=None, use_onesided: bool = True,
                   capacity: Optional[int] = None, enabled=None, nic=None,
                   ds=ht, ptable=None, telemetry=None):
    """Phase 1 of Algorithm 1: lookup_start + one-sided read + lookup_end,
    for any registered data structure (``ds=`` module; default hash table).

    Returns a dict with the per-lane probe outcome: node, cache `hit`,
    one-sided `success` (validated hit), value/version/slot_idx of the hit,
    `need_rpc` (enabled lanes the one-sided read did not RESOLVE — for the
    ordered index a validated miss is resolved without RPC), `enabled`,
    and the read round's WireStats.  The RPC fallback for the `need_rpc`
    lanes can then ride any later exchange round (hybrid_lookup issues it
    immediately; tx's fused protocol piggybacks it on the LOCK round) and be
    folded in with merge_rpc_fallback.

    ``ptable``: optional ``placement.PlacementTable`` — lookup_start routes
    each key to its partition's first LIVE copy instead of the static home
    (identity table when all nodes are up == static home, bit-identical)."""
    if enabled is None:
        enabled = jnp.ones(key_lo.shape, bool)
    if cache is not None and ds.uses_probe_cache(cfg):
        node, off, hit = jax.vmap(
            lambda c, kl, kh: ds.lookup_start(cfg, layout, kl, kh, c,
                                              ptable=ptable)
        )(cache, key_lo, key_hi)
    else:
        node, off, hit = ds.lookup_start(cfg, layout, key_lo, key_hi, None,
                                         ptable=ptable)

    if use_onesided:
        buf, ovf, s_read = osd.remote_read(
            t, state["arena"], node, off, length=ds.probe_words(cfg),
            capacity=capacity, enabled=enabled, nic=nic, telemetry=telemetry,
            phase=T.PH_READ)
        pe = ds.probe_end(cfg, layout, buf, key_lo, key_hi, off, hit)
        success = pe["found"] & ~ovf & enabled
        resolved = pe["resolved"] & ~ovf & enabled
        value, version, slot_idx = pe["value"], pe["version"], pe["slot_idx"]
        need_rpc = ~resolved & enabled
    else:
        success = jnp.zeros(key_lo.shape, bool)
        value = jnp.zeros(key_lo.shape + (sl.VALUE_WORDS,), jnp.uint32)
        version = jnp.zeros(key_lo.shape, jnp.uint32)
        slot_idx = jnp.zeros(key_lo.shape, jnp.uint32)
        s_read = WireStats.zero()
        need_rpc = enabled

    return dict(node=node, hit=hit, success=success, value=value,
                version=version, slot_idx=slot_idx, need_rpc=need_rpc,
                enabled=enabled, wire=s_read)


def merge_rpc_fallback(probe, replies, rpc_ovf):
    """Fold the RPC-fallback replies for `probe["need_rpc"]` lanes into the
    one-sided probe outcome (phase 5 of Algorithm 1).

    Returns dict(found, value, version, slot_idx, rpc_ok, overflow) where
    `overflow` marks lanes whose final-resort RPC was DROPPED by send-queue
    back-pressure — for those, found=False means "not delivered", NOT "key
    absent"."""
    need = probe["need_rpc"]
    rpc_ok = need & (replies[..., 0] == W.ST_OK) & ~rpc_ovf
    value = jnp.where(rpc_ok[..., None], replies[..., 3:], probe["value"])
    version = jnp.where(rpc_ok, replies[..., 2], probe["version"])
    slot_idx = jnp.where(rpc_ok, replies[..., 1], probe["slot_idx"])
    return dict(found=probe["success"] | rpc_ok, value=value, version=version,
                slot_idx=slot_idx, rpc_ok=rpc_ok, overflow=need & rpc_ovf)


def update_lookup_cache(cfg, cache, key_lo, key_hi, node, slot_idx, found,
                        ds=ht):
    """lookup_end's caching duty: remember exact addresses for future
    one-sided reads (no-op when caching is off; the ordered index's
    cache_update is an explicit no-op — its separator cache refreshes
    wholesale via btree.refresh_meta)."""
    if cache is None or not ds.uses_probe_cache(cfg):
        return cache
    return jax.vmap(
        lambda c, kl, kh, nd, si, v: ds.cache_update(cfg, c, kl, kh, nd, si, v)
    )(cache, key_lo, key_hi, node, slot_idx, found)


def hybrid_lookup(t: Transport, state, key_lo, key_hi, cfg, layout, *,
                  cache=None, use_onesided: bool = True,
                  rpc_serial: bool = False, capacity: Optional[int] = None,
                  enabled=None, nic=None, ds=ht, ptable=None, telemetry=None):
    """Batched one-two-sided lookup (any registered data structure via
    ``ds=``; default hash table).

    key_lo/key_hi: (N_local, B) uint32.
    enabled: optional (N_local, B) bool — disabled lanes issue nothing (no
    one-sided read, no RPC, no wire bytes) and report found=False.
    Returns (state, cache, found (N,B), value (N,B,V), version (N,B) uint32,
             owner (N,B) int32, slot_idx (N,B) uint32, overflow (N,B) bool,
             HybridMetrics).  `overflow` marks lanes whose lookup was DROPPED
    by send-queue back-pressure (the RPC fallback overflowed) — for those,
    found=False means "not delivered", NOT "key absent"; transactional
    callers must abort-and-retry them rather than treat the read as a miss.
    """
    probe = onesided_probe(t, state, key_lo, key_hi, cfg, layout, cache=cache,
                           use_onesided=use_onesided, capacity=capacity,
                           enabled=enabled, nic=nic, ds=ds, ptable=ptable,
                           telemetry=telemetry)

    # ---- phase 2: write-based RPC for the failed lanes --------------------
    recs = ds.lookup_records(cfg, key_lo, key_hi)
    handler = (ds.make_rpc_handler(cfg, layout) if rpc_serial
               else ds.make_lookup_handler_vector(cfg, layout))
    state, replies, ovf2, s_rpc = R.rpc_call(
        t, state, probe["node"], recs, handler, capacity=capacity,
        enabled=probe["need_rpc"], nic=nic, telemetry=telemetry,
        phase=T.PH_FALLBACK)
    mg = merge_rpc_fallback(probe, replies, ovf2)

    # ---- lookup_end caching duty ------------------------------------------
    cache = update_lookup_cache(cfg, cache, key_lo, key_hi, probe["node"],
                                mg["slot_idx"], mg["found"], ds=ds)

    metrics = HybridMetrics(
        onesided_success=jnp.sum(probe["success"].astype(jnp.float32)),
        rpc_fallback=jnp.sum(probe["need_rpc"].astype(jnp.float32)),
        total=jnp.sum(probe["enabled"].astype(jnp.float32)),
        wire=probe["wire"] + s_rpc,
    )
    return (state, cache, mg["found"], mg["value"], mg["version"],
            probe["node"], mg["slot_idx"], mg["overflow"], metrics)
