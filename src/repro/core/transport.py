"""Transport layer: the "reliable connected" fabric (Storm §4.2).

Storm's transport decisions map onto TPU as follows: RC connections between
sibling threads become the *static, compiler-scheduled collective* between
SPMD ranks — reliability, ordering and congestion control are properties of
the ICI fabric and the XLA schedule, exactly the "offload it to the NIC"
argument the paper makes for RC.  There is no QP-sharing lock anywhere: every
rank owns its send/recv buffers (Storm's lock-free sibling connections).

The single exchange primitive is dest-major -> source-major:

    exchange(x): x[dst, c, ...] (what THIS node wants delivered to `dst`)
             ->  y[src, c, ...] (what `src` delivered to THIS node)

which is precisely an all-to-all.  Two implementations:

  * SimTransport  — an N-node cluster simulated on one device: cluster arrays
    carry a leading node axis; exchange is a transpose.  Used by the
    benchmarks (this container exposes a single CPU device) and by tests.
  * MeshTransport — the production path: runs inside ``shard_map`` over a mesh
    axis; exchange is ``lax.all_to_all``.  The dry-run proves it lowers and
    compiles on the 512-chip mesh.

Protocol code is written once at cluster level: node-state arrays have one
leading node axis (N, ...); in mesh mode that axis is the per-device shard
(length N/devices, typically 1), so the identical `jax.vmap` per-node code
serves both.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


class Transport:
    n_nodes: int  # global node count

    def exchange(self, x):
        raise NotImplementedError

    def node_ids(self):
        """Global ids of the nodes in this shard: (n_local,) int32."""
        raise NotImplementedError

    @property
    def n_local(self) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SimTransport(Transport):
    """Whole cluster on one device; leading axis = node."""
    n_nodes: int

    def exchange(self, x):
        # x: (N_this, N_dst, C, ...) -> (N_this, N_src, C, ...)
        assert x.shape[0] == self.n_nodes and x.shape[1] == self.n_nodes, x.shape
        return jnp.swapaxes(x, 0, 1)

    def node_ids(self):
        return jnp.arange(self.n_nodes, dtype=jnp.int32)

    @property
    def n_local(self) -> int:
        return self.n_nodes


@dataclasses.dataclass(frozen=True)
class MeshTransport(Transport):
    """Inside shard_map over `axis_name`, one node per device (n_local == 1).
    Local arrays: (1, N, C, ...)."""
    n_nodes: int
    axis_name: str = "node"

    def exchange(self, x):
        # x: (1, N_dst, C, ...) dest-major.  tiled all_to_all splits axis 1
        # into axis_size chunks (each (1, 1, C, ...)), sends chunk i to rank
        # i, concatenates received chunks on axis 0 -> (N, 1, C, ...).  The
        # swap restores the (n_local=1, N_src, C, ...) source-major layout.
        y = lax.all_to_all(x, self.axis_name, split_axis=1, concat_axis=0, tiled=True)
        return jnp.swapaxes(y, 0, 1)

    def node_ids(self):
        i = lax.axis_index(self.axis_name)
        return jnp.asarray(i, jnp.int32)[None]

    @property
    def n_local(self) -> int:
        return 1


# ---------------------------------------------------------------------------
# Client-side routing: pack per-lane requests into the dest-major send buffer.
# This is the coroutine scheduler's doorbell batching: B outstanding lanes per
# node, sorted by destination, with a fixed per-destination capacity C
# (overflowed lanes report failure and retry at the app level — the same
# back-pressure a real send queue applies).  Everything headed for one
# destination shares ONE contiguous buffer chunk, so the exchange puts one
# coalesced message per live (src, dst) pair on the wire (Storm's doorbell
# batching); wire_for accounts accordingly.
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnums=(2, 3))
def route_by_dest(dest, payload, n_dst: int, capacity: int, enabled=None):
    """dest: (B,) int32 in [0, n_dst); payload: (B, W) uint32.

    enabled: optional (B,) bool — lanes that actually issue a request this
    round.  Disabled lanes are parked in the trash column and, crucially, do
    NOT consume destination capacity, so a retry round that re-enables only
    the previously-overflowed lanes can always make progress.

    A dest outside [0, n_dst) is parked exactly like a disabled lane: the
    placement layer (core/placement.py) encodes "no reachable copy" as
    dest = -1, and a parked lane reads back ST_DROPPED — an unreachable
    partition surfaces as retryable back-pressure, never as a wrapped-around
    delivery to some arbitrary node.

    Returns:
      buf      (n_dst, capacity, W) uint32 — dest-major send buffer
      mask     (n_dst, capacity)    bool   — which cells hold live requests
      pos      (B,)                 int32  — cell index of each lane (for reply
                                            pickup; == capacity for parked lanes)
      overflow (B,)                 bool   — enabled lanes dropped by capacity
    """
    B = dest.shape[0]
    dest = dest.astype(jnp.int32)
    live = jnp.ones((B,), bool) if enabled is None else enabled
    # out-of-range dests (placement's "unreachable" sentinel -1) are parked
    live = live & (dest >= 0) & (dest < n_dst)
    dest = jnp.clip(dest, 0, n_dst - 1)
    # rank of each lane within its destination group (stable order, live only)
    onehot = ((dest[:, None] == jnp.arange(n_dst, dtype=jnp.int32)[None, :])
              & live[:, None])
    pos = (jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1)[jnp.arange(B), dest]
    overflow = live & (pos >= capacity)
    # overflowed and disabled lanes land in a trash column that is sliced off,
    # so they can never clobber live cells (the send queue's back-pressure
    # drop).  pick_replies recognizes pos == capacity as "no cell".
    pos = jnp.where(live & ~overflow, pos, capacity)
    buf = jnp.zeros((n_dst, capacity + 1, payload.shape[-1]), jnp.uint32)
    buf = buf.at[dest, pos].set(payload.astype(jnp.uint32))
    mask = jnp.zeros((n_dst, capacity + 1), bool)
    mask = mask.at[dest, pos].set(live)
    return buf[:, :capacity], mask[:, :capacity], pos, overflow


def placement_dest(copies, alive, part):
    """Resolve a partition to its first LIVE copy under a placement table.

    copies: (n_parts, K) int32 — copy list per partition, column 0 = owner,
            -1 = no copy in that slot (core/placement.py's PlacementTable).
    alive:  (n_nodes,) bool.
    part:   int32, any batch shape.

    Returns (dest, reachable): dest is the first copy (owner-priority order)
    whose node is alive, or -1 when every copy is dead — which route_by_dest
    parks, so an unreachable partition becomes ST_DROPPED back-pressure.
    This one scan is THE failover rule: replication.failover_dest and the
    read-side failover paths all reduce to it.
    """
    row = copies[part]                                   # (..., K)
    ok = (row >= 0) & alive[jnp.clip(row, 0, alive.shape[0] - 1)]
    idx = jnp.argmax(ok, axis=-1)                        # first live slot
    reachable = jnp.any(ok, axis=-1)
    dest = jnp.take_along_axis(row, idx[..., None], axis=-1)[..., 0]
    return jnp.where(reachable, dest, -1).astype(jnp.int32), reachable


def route_by_placement(table, part, payload, n_dst: int, capacity: int,
                       enabled=None):
    """route_by_dest with the destination resolved THROUGH a placement table
    instead of supplied by static partition math.

    table: anything with ``.copies`` (n_parts, K) int32 and ``.alive``
    (n_nodes,) bool — i.e. a core/placement.py PlacementTable.  part: (B,)
    int32 partition of each lane.  Lanes whose partition has no live copy
    route to -1 and are parked (ST_DROPPED).

    Returns (dest, reachable, buf, mask, pos, overflow) — the extra leading
    pair lets callers thread dest into reply pickup and surface
    ``dead_route = enabled & ~reachable``.
    """
    dest, reachable = placement_dest(table.copies, table.alive, part)
    buf, mask, pos, overflow = route_by_dest(dest, payload, n_dst, capacity,
                                             enabled)
    return dest, reachable, buf, mask, pos, overflow


def pick_replies(replies, dest, pos, overflow):
    """replies: (n_dst, C, W) dest-major reply buffer (post-exchange);
    returns per-lane replies (B, W).  Lanes without a live cell (overflowed or
    parked at pos >= C) read back zeros — callers are responsible for not
    treating those as real replies (rpc.rpc_call stamps ST_DROPPED)."""
    C = replies.shape[1]
    if C == 0:
        # zero-capacity round: no cell was ever live, every lane reads zeros
        # (a capacity=0 configuration back-pressures everything, not nothing)
        return jnp.zeros(dest.shape + (replies.shape[-1],), replies.dtype)
    invalid = overflow | (pos >= C)
    out = replies[dest, jnp.where(invalid, 0, pos)]
    return jnp.where(invalid[:, None], jnp.zeros_like(out), out)


# ---------------------------------------------------------------------------
# Wire accounting — the hardware-independent metrics the benchmarks report
# (round trips / messages / bytes per op), mirroring the quantities Storm
# reasons about in §4.4-4.5.  When a connection table (core.nic.ConnTable) is
# supplied, every round additionally carries the modeled NIC-cache hit rate
# and per-op connection-state penalty of the transport configuration it ran
# under (§2.2/Fig. 7) — both stored ops-weighted so stats stay additive.
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WireStats:
    round_trips: jnp.ndarray   # scalar f32 — network round trips issued
    messages: jnp.ndarray      # scalar f32 — coalesced messages on the wire
    ops: jnp.ndarray           # scalar f32 — application-level requests (IOPS)
    req_bytes: jnp.ndarray     # scalar f32
    reply_bytes: jnp.ndarray   # scalar f32
    # NIC connection-state model (ops-weighted so `+` stays exact):
    nic_hit_ops: jnp.ndarray = dataclasses.field(     # sum(ops * cache_hit)
        default_factory=lambda: jnp.zeros((), jnp.float32))
    nic_penalty_us: jnp.ndarray = dataclasses.field(  # sum(ops * penalty_us)
        default_factory=lambda: jnp.zeros((), jnp.float32))

    @staticmethod
    def zero():
        # field-driven so the NEXT added field is zeroed automatically instead
        # of silently breaking a positional constructor (regression-tested by
        # tests/test_telemetry.py::test_wirestats_zero_roundtrips_every_field)
        return WireStats(**{f.name: jnp.zeros((), jnp.float32)
                            for f in dataclasses.fields(WireStats)})

    def __add__(self, o):
        return WireStats(**{f.name: getattr(self, f.name) + getattr(o, f.name)
                            for f in dataclasses.fields(WireStats)})

    @property
    def total_bytes(self):
        return self.req_bytes + self.reply_bytes

    @property
    def nic_hit_rate(self):
        """Ops-weighted modeled NIC-cache hit rate (1.0 when no ConnTable
        was threaded through — an un-modeled fabric misses nothing)."""
        return jnp.where(self.ops > 0,
                         self.nic_hit_ops / jnp.maximum(self.ops, 1.0), 1.0)

    @property
    def nic_penalty_us_per_op(self):
        """Ops-weighted modeled per-op connection-state penalty (us)."""
        return jnp.where(self.ops > 0,
                         self.nic_penalty_us / jnp.maximum(self.ops, 1.0), 0.0)


def _nic_terms(ops, nic):
    """ops-weighted (hit, penalty) terms for one round; nic is a static
    core.nic.ConnTable (or None = perfect, penalty-free NIC)."""
    if nic is None:
        return ops, jnp.zeros((), jnp.float32)
    return ops * nic.cache_hit, ops * nic.penalty_us_per_op


def wire_for(mask, req_words: int, reply_words: int, header_words: int = 1,
             nic=None):
    """Stats for one exchange round given the live-cell mask (..., n_dst, C).

    Requests headed for the same destination ride ONE coalesced wire message
    per live (src, dst) pair — Storm's doorbell batching — and likewise for
    the replies coming back, so `messages` counts live pairs (both ways) while
    `ops` keeps the per-request count the paper reports as IOPS.  Each
    coalesced message pays the header once; each record pays its payload.

    The single header word is the immediate: it packs the (src, slot) reply
    coordinates AND the sender's placement-table epoch (core/placement.py).
    Epoch bumps therefore add zero bytes per record — staleness is detected
    owner-side against the published routing region and surfaced as
    ST_WRONG_EPOCH, so the epoch-stable wire format is unchanged.
    """
    live = jnp.sum(mask.astype(jnp.float32))
    pairs = jnp.sum(jnp.any(mask, axis=-1).astype(jnp.float32))
    reply_pairs = pairs if reply_words > 0 else jnp.zeros((), jnp.float32)
    hit_ops, penalty_us = _nic_terms(live, nic)
    return WireStats(
        # a round with no live (src, dst) pair puts nothing on the wire and
        # therefore costs no round trip (e.g. a fully-parked retry round)
        round_trips=(pairs > 0).astype(jnp.float32),
        messages=pairs + reply_pairs,
        ops=live,
        req_bytes=live * 4.0 * req_words + pairs * 4.0 * header_words,
        reply_bytes=live * 4.0 * reply_words + reply_pairs * 4.0 * header_words,
        nic_hit_ops=hit_ops,
        nic_penalty_us=penalty_us,
    )


def wire_for_classes(masks, req_words, reply_words, header_words: int = 1,
                     nic=None):
    """Coalesced stats for ONE fused exchange round carrying several traffic
    classes (roundsched.fused_round).

    masks: list of live-cell masks, each (..., n_dst, C_k); req_words /
    reply_words: per-class word counts.  All classes headed for one
    destination ride the SAME coalesced wire message — a (src, dst) pair is
    counted ONCE no matter how many classes it carries (the true
    doorbell-batching accounting), while `ops` still counts every delivered
    application-level request.

    This is also how the replicated commit is priced: its backup-write
    classes widen the round's (src, dst) fan-out and add delivered requests
    (each paying the nic model's per-op connection-state penalty) without
    adding a round trip — `round_trips` stays 1 for the whole fused round.
    """
    f32 = jnp.float32
    zero = jnp.zeros((), f32)
    live = [jnp.sum(m.astype(f32)) for m in masks]
    ops = sum(live, zero)
    pair_live = None
    reply_pair_live = None
    for m, rw in zip(masks, reply_words):
        a = jnp.any(m, axis=-1)
        pair_live = a if pair_live is None else (pair_live | a)
        if rw > 0:
            reply_pair_live = a if reply_pair_live is None else (reply_pair_live | a)
    pairs = zero if pair_live is None else jnp.sum(pair_live.astype(f32))
    reply_pairs = (zero if reply_pair_live is None
                   else jnp.sum(reply_pair_live.astype(f32)))
    req_bytes = sum((l * 4.0 * w for l, w in zip(live, req_words)), zero)
    reply_bytes = sum((l * 4.0 * w for l, w in zip(live, reply_words)), zero)
    hit_ops, penalty_us = _nic_terms(ops, nic)
    return WireStats(
        round_trips=(pairs > 0).astype(f32),
        messages=pairs + reply_pairs,
        ops=ops,
        req_bytes=req_bytes + pairs * 4.0 * header_words,
        reply_bytes=reply_bytes + reply_pairs * 4.0 * header_words,
        nic_hit_ops=hit_ops,
        nic_penalty_us=penalty_us,
    )


def per_dest_wire(masks, req_words, reply_words, header_words: int = 1):
    """Per-DESTINATION view of :func:`wire_for_classes` for one fused round.

    masks: list of live-cell masks, each (N_src, n_dst, C_k).  Returns
    ``(msgs, bytes)`` — two (n_dst,) float32 vectors counting the coalesced
    wire messages addressed to / replied by each destination and their total
    bytes (both directions), with the same coalescing rules as the scalar
    accounting: summing either vector over destinations reproduces the
    round's ``WireStats.messages`` / ``total_bytes`` exactly (asserted by
    tests/test_telemetry.py).  Consumed by the flight recorder's per-dest
    event-row tails (core/telemetry.py).
    """
    f32 = jnp.float32
    n_dst = masks[0].shape[-2]
    zero = jnp.zeros((n_dst,), f32)
    live = [jnp.sum(m.astype(f32), axis=(0, -1)) for m in masks]   # (n_dst,)
    pair_live = None
    reply_pair_live = None
    for m, rw in zip(masks, reply_words):
        a = jnp.any(m, axis=-1)                                    # (N, n_dst)
        pair_live = a if pair_live is None else (pair_live | a)
        if rw > 0:
            reply_pair_live = a if reply_pair_live is None else (reply_pair_live | a)
    pairs = zero if pair_live is None else jnp.sum(pair_live.astype(f32), axis=0)
    reply_pairs = (zero if reply_pair_live is None
                   else jnp.sum(reply_pair_live.astype(f32), axis=0))
    req_bytes = sum((l * 4.0 * w for l, w in zip(live, req_words)), zero)
    reply_bytes = sum((l * 4.0 * w for l, w in zip(live, reply_words)), zero)
    msgs = pairs + reply_pairs
    byts = (req_bytes + reply_bytes + pairs * 4.0 * header_words
            + reply_pairs * 4.0 * header_words)
    return msgs, byts
