"""Multi-round transaction engine: bounded retry with backoff (Storm §5.4).

``tx.run_transactions`` is single shot: a lane that loses a lock race, fails
OCC validation, or is dropped by send-queue back-pressure simply reports
failure.  Storm's dataplane instead *retries* aborted transactions — under
contention the batch converges instead of silently dropping work.  ``tx_loop``
drives that retry:

  * a ``lax.scan`` over ``max_rounds`` protocol rounds, all shapes static;
  * per-round lane re-enable masks: lanes that committed are parked (their
    reads/writes are disabled, so they cost no handler work, no send-queue
    capacity and no wire bytes — see transport.route_by_dest's enabled mask);
    lanes that aborted for ANY cause (lock conflict, validation conflict,
    overflow) stay live and re-execute the full OCC protocol;
  * randomized-slot backoff: each round >= 1 permutes the surviving lanes'
    send-queue slots with a per-round PRNG draw, which re-randomizes the lock
    serialization order so one pathological ordering cannot starve the same
    lane round after round (the batched analogue of randomized exponential
    backoff).

Because committed lanes release send-queue capacity, a workload that
overflows a small per-destination capacity drains across rounds — every lane
is eventually delivered (see tests/test_txloop.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import hybrid as hy
from repro.core import placement as pl
from repro.core import slots as sl
from repro.core import telemetry as T
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxLoopResult:
    committed: jnp.ndarray            # (N, B) bool — committed in ANY round
    commit_round: jnp.ndarray         # (N, B) int32 — round of commit, -1 if never
    read_found: jnp.ndarray           # (N, B, R) bool — from the lane's last attempt
    read_values: jnp.ndarray          # (N, B, R, VALUE_WORDS)
    # --- per-round metrics, each (max_rounds,) int32 -----------------------
    round_committed: jnp.ndarray      # lanes that committed in round r
    round_attempts: jnp.ndarray       # live lanes entering round r
    round_retries: jnp.ndarray        # live lanes in round r > 0 (re-attempts)
    round_abort_lock: jnp.ndarray     # aborts by cause, per round
    round_abort_validate: jnp.ndarray
    round_abort_overflow: jnp.ndarray
    round_abort_stale: jnp.ndarray    # stale placement routes, per round
    metrics: hy.HybridMetrics         # totals across all rounds
    round_trips: jnp.ndarray          # scalar


def _perm_lanes(x, perm):
    """Permute the lane axis (axis 1) of (N, B, ...) by perm (N, B)."""
    idx = perm.reshape(perm.shape + (1,) * (x.ndim - 2))
    return jnp.take_along_axis(x, idx, axis=1)


def tx_loop(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
            read_keys, write_keys, write_values, read_enabled=None,
            write_enabled=None, cache=None, use_onesided: bool = True,
            capacity: Optional[int] = None, max_rounds: int = 4, key=None,
            fused: bool = True, nic=None, rep=None, ptable=None, pcfg=None,
            telemetry: Optional[T.TelemetryConfig] = None):
    """Run a batch of transactions to convergence (bounded by max_rounds).

    Arguments mirror tx.run_transactions; additionally:
      max_rounds: static retry bound (>= 1).  Round 0 is identical to the
                  single-shot protocol; each later round re-runs only the
                  still-aborted lanes with permuted send-queue slots.
      key:        optional jax PRNG key for the backoff permutation.
      fused:      run each protocol round on the fused 3-4-exchange schedule
                  (default) or the per-phase 5-round reference.
      nic:        optional repro.core.nic.ConnTable (connection mode +
                  emulated cluster scale); the aggregated metrics.wire then
                  reports the modeled NIC-cache hit rate / per-op penalty.
      rep:        optional repro.core.replication.ReplicaConfig — every
                  committing round installs the write set on all f+1 copies
                  (backup writes fused into the commit round, zero extra
                  exchange rounds); a backup write dropped by back-pressure
                  aborts its lane (cause: overflow), which THIS loop retries.
      ptable/pcfg: optional placement.PlacementTable + PlacementConfig —
                  every round routes through the table, and a retry round
                  entered with stale-route aborts (``aborted_stale``, i.e.
                  some owner answered ST_WRONG_EPOCH) first REFRESHES the
                  table with one one-sided read of the coordinator's routing
                  region, mirroring scan_loop's separator-directory refresh.
                  Epoch-stable rounds never refresh — the read is
                  enabled-gated off, so the steady-state round-trip schedule
                  is EXACTLY the pre-placement one (bench-gated).
      telemetry:  optional telemetry.TelemetryConfig — thread a flight
                  recorder through every exchange round (one event per fused
                  round + one summary per protocol round) and accumulate the
                  modeled per-lane latency.  ``None`` (default) is
                  bit-identical and round-identical to a recorder-free build.

    Returns (state, cache, TxLoopResult) — plus a ``telemetry.TelemetryOut``
    as a fourth element when ``telemetry`` is enabled.
    """
    N, B, Rd = read_keys.shape[:3]
    if read_enabled is None:
        read_enabled = jnp.ones(read_keys.shape[:3], bool)
    if write_enabled is None:
        write_enabled = jnp.ones(write_keys.shape[:3], bool)
    if key is None:
        key = jax.random.PRNGKey(0x5707)
    use_pl = ptable is not None
    if use_pl and pcfg is None:
        raise ValueError("tx_loop: ptable requires pcfg (PlacementConfig)")
    use_tel = telemetry is not None
    ident = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None], (N, B))

    def body(carry, rnd):
        state, cache, ptab, stale_in, done, commit_round, rfound, rvals, \
            key, tb, lat = carry
        rec = T.Recorder(telemetry, tb) if use_tel else None
        if use_tel:
            rec.set_round(rnd)
            n0 = rec.buf.n
        key, sub = jax.random.split(key)
        perm = jax.vmap(lambda k: jax.random.permutation(k, B))(
            jax.random.split(sub, N)).astype(jnp.int32)
        perm = jnp.where(rnd == 0, ident, perm)     # round 0 == single shot
        inv = jnp.argsort(perm, axis=1)
        active = ~done
        p = lambda x: _perm_lanes(x, perm)
        u = lambda x: _perm_lanes(x, inv)
        act_p = p(active)

        # a retry round entered with stale-route aborts refreshes the cached
        # placement table first (one one-sided read of the coordinator's
        # routing region); epoch-stable rounds gate the read OFF — zero wire,
        # zero round trips — so the fast-path schedule stays untouched
        s_ref = hy.WireStats.zero()
        if use_pl:
            want = (rnd > 0) & stale_in
            ptab_new, s_r = pl.refresh_table(t, state, layout, pcfg, ptab,
                                             enabled=want, nic=nic,
                                             telemetry=rec)
            ptab = jax.tree.map(
                lambda new, old: jnp.where(want, new, old), ptab_new, ptab)
            s_ref = jax.tree.map(
                lambda x: jnp.where(want, x, jnp.zeros_like(x)), s_r)

        state, cache, res = txm.run_transactions(
            t, state, cfg, layout,
            read_keys=p(read_keys), write_keys=p(write_keys),
            write_values=p(write_values),
            read_enabled=p(read_enabled) & act_p[..., None],
            write_enabled=p(write_enabled) & act_p[..., None],
            cache=cache, use_onesided=use_onesided, capacity=capacity,
            fused=fused, nic=nic, rep=rep,
            ptable=ptab if use_pl else None, telemetry=rec)
        # fully-masked (parked) lanes report committed=True — gate on active
        newly = u(res.committed) & active
        done = done | newly
        commit_round = jnp.where(newly, rnd.astype(jnp.int32), commit_round)
        rfound = jnp.where(active[..., None], u(res.read_found), rfound)
        rvals = jnp.where(active[..., None, None], u(res.read_values), rvals)
        count = lambda x: jnp.sum(x.astype(jnp.int32))
        stale_out = jnp.any(u(res.aborted_stale) & active)
        m = res.metrics
        stats = dict(
            committed=count(newly),
            attempts=count(active),
            retries=jnp.where(rnd > 0, count(active), 0),
            abort_lock=count(u(res.aborted_lock) & active),
            abort_validate=count(u(res.aborted_validate) & active),
            abort_overflow=count(u(res.aborted_overflow) & active),
            abort_stale=count(u(res.aborted_stale) & active),
            metrics=hy.HybridMetrics(m.onesided_success, m.rpc_fallback,
                                     m.total, m.wire + s_ref),
            round_trips=res.round_trips + s_ref.round_trips,
        )
        if use_tel:
            # every lane still live this round accumulates the round's
            # modeled latency; the summary row carries the abort vector
            lat = lat + rec.round_cost_us(n0) * active.astype(jnp.float32)
            rec.summary(committed=stats["committed"],
                        attempts=stats["attempts"],
                        abort_lock=stats["abort_lock"],
                        abort_validate=stats["abort_validate"],
                        abort_overflow=stats["abort_overflow"],
                        abort_stale=stats["abort_stale"])
            tb = rec.buf
        return (state, cache, ptab, stale_out, done, commit_round, rfound,
                rvals, key, tb, lat), stats

    init = (
        state, cache,
        ptable if use_pl else jnp.zeros(()),
        jnp.zeros((), bool),
        jnp.zeros((N, B), bool),
        jnp.full((N, B), -1, jnp.int32),
        jnp.zeros(read_enabled.shape, bool),
        jnp.zeros(read_enabled.shape + (sl.VALUE_WORDS,), jnp.uint32),
        key,
        (T.make_buffer(t.n_nodes, T.loop_capacity(telemetry, max_rounds))
         if use_tel else jnp.zeros(())),
        jnp.zeros((N, B), jnp.float32) if use_tel else jnp.zeros(()),
    )
    (state, cache, _, _, done, commit_round, rfound, rvals, _, tb,
     lat), ys = lax.scan(body, init, jnp.arange(max_rounds))

    metrics = jax.tree.map(lambda x: jnp.sum(x, axis=0), ys["metrics"])
    result = TxLoopResult(
        committed=done,
        commit_round=commit_round,
        read_found=rfound,
        read_values=rvals,
        round_committed=ys["committed"],
        round_attempts=ys["attempts"],
        round_retries=ys["retries"],
        round_abort_lock=ys["abort_lock"],
        round_abort_validate=ys["abort_validate"],
        round_abort_overflow=ys["abort_overflow"],
        round_abort_stale=ys["abort_stale"],
        metrics=metrics,
        round_trips=jnp.sum(ys["round_trips"]),
    )
    if use_tel:
        return state, cache, result, T.TelemetryOut(trace=tb,
                                                    lane_latency_us=lat)
    return state, cache, result


# ===========================================================================
# Bounded-retry loop for RANGE-SCAN transactions (tx.run_scan_transactions).
#
# Same engine shape as tx_loop — committed lanes park, aborted lanes re-run
# with randomized-slot backoff — plus one ordered-index-specific move: every
# retry round REFRESHES the cached separator directory first (one one-sided
# read per node, its wire cost accounted), so lanes that aborted on a stale
# plan (a leaf split underneath the scan: fence-chain gap -> cause
# `validate`) converge instead of replaying the same stale route — the
# retry-loop analogue of chasing a B-link right-pointer.  `truncated` lanes
# (range needs more than cfg.max_scan_leaves leaves) are parked and REPORTED:
# retrying cannot help and a silent clip is never returned as success.
# ===========================================================================
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScanLoopResult:
    committed: jnp.ndarray            # (N, B) bool — committed in ANY round
    commit_round: jnp.ndarray         # (N, B) int32 — round of commit, -1 never
    truncated: jnp.ndarray            # (N, B) bool — parked: range > S leaves
    scan_keys: jnp.ndarray            # (N, B, S, LW) — from the last attempt
    scan_values: jnp.ndarray          # (N, B, S, LW, VALUE_WORDS)
    scan_mask: jnp.ndarray            # (N, B, S, LW) bool
    # --- per-round metrics, each (max_rounds,) int32 -----------------------
    round_committed: jnp.ndarray
    round_attempts: jnp.ndarray
    round_retries: jnp.ndarray
    round_abort_lock: jnp.ndarray
    round_abort_validate: jnp.ndarray
    round_abort_overflow: jnp.ndarray
    round_abort_stale: jnp.ndarray    # stale placement routes, per round
    metrics: hy.HybridMetrics         # totals across rounds (+ meta refresh)
    round_trips: jnp.ndarray          # scalar


def scan_loop(t: Transport, state, cfg, layout, *, scan_lo, scan_hi,
              meta=None, write_keys=None, write_values=None,
              scan_enabled=None, write_enabled=None,
              capacity: Optional[int] = None, max_rounds: int = 4, key=None,
              fused: bool = True, nic=None, rep=None, refresh: bool = True,
              ptable=None, pcfg=None,
              telemetry: Optional[T.TelemetryConfig] = None):
    """Run a batch of range-scan transactions to convergence.

    Arguments mirror tx.run_scan_transactions (cfg is a btree.BTreeConfig);
    additionally:
      meta:       initial cached separator directory; None fetches one up
                  front (wire cost counted).
      refresh:    refresh the directory before every RETRY round (default) —
                  stale-plan aborts then converge; refresh=False replays the
                  initial meta (useful to demonstrate the livelock it avoids).
      ptable/pcfg: optional placement table + config — lock-class routing
                  goes through the table; a retry round entered with
                  stale-route aborts refreshes it first (enabled-gated read,
                  zero wire on epoch-stable rounds — same idiom as the
                  separator-directory refresh above).
      telemetry:  optional telemetry.TelemetryConfig — same flight recorder
                  as tx_loop's (``None`` = bit-identical, round-identical).
    Returns (state, meta, ScanLoopResult) — plus a ``telemetry.TelemetryOut``
    as a fourth element when ``telemetry`` is enabled."""
    from repro.core.datastructs import btree as bt

    N, B = scan_lo.shape
    S, LW = cfg.max_scan_leaves, cfg.leaf_width
    if write_keys is None:
        write_keys = jnp.zeros((N, B, 0), jnp.uint32)
        write_values = jnp.zeros((N, B, 0, sl.VALUE_WORDS), jnp.uint32)
    Wr = write_keys.shape[2]
    if scan_enabled is None:
        scan_enabled = jnp.ones((N, B), bool)
    if write_enabled is None:
        write_enabled = jnp.ones((N, B, Wr), bool)
    if key is None:
        key = jax.random.PRNGKey(0x5C0A)
    use_pl = ptable is not None
    if use_pl and pcfg is None:
        raise ValueError("scan_loop: ptable requires pcfg (PlacementConfig)")
    use_tel = telemetry is not None
    tb0 = (T.make_buffer(t.n_nodes, T.loop_capacity(telemetry, max_rounds))
           if use_tel else jnp.zeros(()))
    init_wire = hy.WireStats.zero()
    if meta is None:
        meta, s0 = bt.refresh_meta(t, state, cfg, layout, nic=nic)
        init_wire = init_wire + s0
        if use_tel:
            # up-front directory fetch: one event stamped "round -1" (per-dest
            # tails: the refresh is a uniform all-to-all, scalar split evenly)
            rec0 = T.Recorder(telemetry, tb0)
            rec0.set_round(-1)
            nd = t.n_nodes
            rec0.record(
                T.PH_REFRESH, s0,
                per_dest_msgs=jnp.full((nd,), s0.messages / nd),
                per_dest_bytes=jnp.full(
                    (nd,), (s0.req_bytes + s0.reply_bytes) / nd))
            tb0 = rec0.buf
    ident = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None], (N, B))

    def body(carry, rnd):
        (state, meta, ptab, stale_in, done, trunc, commit_round, skeys, svals,
         smask, key, tb, lat) = carry
        rec = T.Recorder(telemetry, tb) if use_tel else None
        if use_tel:
            rec.set_round(rnd)
            n0 = rec.buf.n
        key, sub = jax.random.split(key)
        perm = jax.vmap(lambda k: jax.random.permutation(k, B))(
            jax.random.split(sub, N)).astype(jnp.int32)
        perm = jnp.where(rnd == 0, ident, perm)     # round 0 == single shot
        inv = jnp.argsort(perm, axis=1)
        active = ~done
        p = lambda x: _perm_lanes(x, perm)
        u = lambda x: _perm_lanes(x, inv)
        act_p = p(active)

        s_ref = hy.WireStats.zero()
        if refresh:
            meta_new, s_r = bt.refresh_meta(t, state, cfg, layout, nic=nic)
            use = rnd > 0
            meta = jax.tree.map(
                lambda new, old: jnp.where(use, new, old), meta_new, meta)
            s_ref = jax.tree.map(
                lambda x: jnp.where(use, x, jnp.zeros_like(x)), s_r)
            if use_tel:
                # the directory read itself is issued unconditionally but
                # ACCOUNTED only on retry rounds — record the gated view so
                # the trace matches the wire accounting exactly; the refresh
                # is a uniform all-to-all (every node reads every node), so
                # the per-dest tails are the scalar split evenly
                nd = t.n_nodes
                rec.record(
                    T.PH_REFRESH, s_ref,
                    per_dest_msgs=jnp.full((nd,), s_ref.messages / nd),
                    per_dest_bytes=jnp.full(
                        (nd,), (s_ref.req_bytes + s_ref.reply_bytes) / nd))
        if use_pl:
            # placement-table refresh, gated exactly like tx_loop's: only a
            # retry round entered with stale-route aborts pays the read
            want = (rnd > 0) & stale_in
            ptab_new, s_p = pl.refresh_table(t, state, layout, pcfg, ptab,
                                             enabled=want, nic=nic,
                                             telemetry=rec)
            ptab = jax.tree.map(
                lambda new, old: jnp.where(want, new, old), ptab_new, ptab)
            s_ref = s_ref + jax.tree.map(
                lambda x: jnp.where(want, x, jnp.zeros_like(x)), s_p)

        state, res = txm.run_scan_transactions(
            t, state, cfg, layout,
            scan_lo=p(scan_lo), scan_hi=p(scan_hi), meta=meta,
            write_keys=p(write_keys), write_values=p(write_values),
            scan_enabled=p(scan_enabled) & act_p,
            write_enabled=p(write_enabled) & act_p[..., None],
            capacity=capacity, fused=fused, nic=nic, rep=rep,
            ptable=ptab if use_pl else None, telemetry=rec)
        newly = u(res.committed) & active
        newly_trunc = u(res.truncated) & active
        done = done | newly | newly_trunc           # truncation cannot retry
        trunc = trunc | newly_trunc
        commit_round = jnp.where(newly, rnd.astype(jnp.int32), commit_round)
        upd = active[..., None, None]
        skeys = jnp.where(upd, u(res.scan_keys), skeys)
        smask = jnp.where(upd, u(res.scan_mask), smask)
        svals = jnp.where(upd[..., None], u(res.scan_values), svals)
        count = lambda x: jnp.sum(x.astype(jnp.int32))
        stale_out = jnp.any(u(res.aborted_stale) & active)
        m = res.metrics
        stats = dict(
            committed=count(newly),
            attempts=count(active),
            retries=jnp.where(rnd > 0, count(active), 0),
            abort_lock=count(u(res.aborted_lock) & active),
            abort_validate=count(u(res.aborted_validate) & active),
            abort_overflow=count(u(res.aborted_overflow) & active),
            abort_stale=count(u(res.aborted_stale) & active),
            metrics=hy.HybridMetrics(m.onesided_success, m.rpc_fallback,
                                     m.total, m.wire + s_ref),
            round_trips=res.round_trips + s_ref.round_trips,
        )
        if use_tel:
            lat = lat + rec.round_cost_us(n0) * active.astype(jnp.float32)
            rec.summary(committed=stats["committed"],
                        attempts=stats["attempts"],
                        abort_lock=stats["abort_lock"],
                        abort_validate=stats["abort_validate"],
                        abort_overflow=stats["abort_overflow"],
                        abort_stale=stats["abort_stale"])
            tb = rec.buf
        return (state, meta, ptab, stale_out, done, trunc, commit_round,
                skeys, svals, smask, key, tb, lat), stats

    init = (
        state, meta,
        ptable if use_pl else jnp.zeros(()),
        jnp.zeros((), bool),
        jnp.zeros((N, B), bool),
        jnp.zeros((N, B), bool),
        jnp.full((N, B), -1, jnp.int32),
        jnp.zeros((N, B, S, LW), jnp.uint32),
        jnp.zeros((N, B, S, LW, sl.VALUE_WORDS), jnp.uint32),
        jnp.zeros((N, B, S, LW), bool),
        key,
        tb0,
        jnp.zeros((N, B), jnp.float32) if use_tel else jnp.zeros(()),
    )
    (state, meta, _, _, done, trunc, commit_round, skeys, svals, smask,
     _, tb, lat), ys = lax.scan(body, init, jnp.arange(max_rounds))

    metrics = jax.tree.map(lambda x: jnp.sum(x, axis=0), ys["metrics"])
    metrics = hy.HybridMetrics(metrics.onesided_success, metrics.rpc_fallback,
                               metrics.total, metrics.wire + init_wire)
    result = ScanLoopResult(
        committed=done & ~trunc,
        commit_round=commit_round,
        truncated=trunc,
        scan_keys=skeys, scan_values=svals, scan_mask=smask,
        round_committed=ys["committed"],
        round_attempts=ys["attempts"],
        round_retries=ys["retries"],
        round_abort_lock=ys["abort_lock"],
        round_abort_validate=ys["abort_validate"],
        round_abort_overflow=ys["abort_overflow"],
        round_abort_stale=ys["abort_stale"],
        metrics=metrics,
        round_trips=jnp.sum(ys["round_trips"]) + init_wire.round_trips,
    )
    if use_tel:
        return state, meta, result, T.TelemetryOut(trace=tb,
                                                   lane_latency_us=lat)
    return state, meta, result
