"""Multi-class fused round scheduler (Storm §4.5 doorbell batching, Fig. 3).

Storm's latency argument is round trips: independent protocol phases have no
business occupying separate all-to-alls.  ``fused_round`` is the one exchange
primitive everything else is built on: it takes several *traffic classes* in a
single call — each class = (dest, payload, reply shape, owner-side action) —
packs them into ONE dest-major send buffer, performs ONE all-to-all each way,
runs each class's owner action over its sub-inbox, and returns per-class
replies and overflow masks plus a single coalesced :class:`WireStats`.

Traffic classes:

  * ``read_class``  — one-sided read: the payload is a word offset, the owner
    action is pure address translation + gather (no application logic).
  * ``rpc_class``   — write-based RPC: the payload is a request record, the
    owner runs the registered handler (serial = mutating fold, vector =
    read-only map).

Owner-side ordering inside one fused round is fixed and documented, because
it is what makes fusing OCC phases legal:

  1. **vector handlers** observe the round's PRE-handler state (a read-only
     RPC fused with a mutating class sees the state as if it ran in its own
     earlier round — how tx fuses the read-set lookup fallback with LOCK);
  2. **serial handlers** fold through node state in class order, each with
     genuine serialization semantics (scan order = lock order);
  3. **one-sided gathers** run LAST, on the post-handler state (the owner
     drains its RPC inbox before serving the round's reads — how tx fuses
     VALIDATE re-reads into the same round as the locks they must observe).

Buffer layout: each class reserves its own per-destination sub-budget
(``capacity``, defaulting to its lane count), and the shared send buffer is
the concatenation of the class segments — so the per-destination budget of
the fused message is the sum of the class budgets, each class's overflow
behaviour is identical to the round it replaced, and every class's sub-inbox
is a contiguous slice.  All classes headed for one destination still ride ONE
coalesced wire message per live (src, dst) pair each way; ``wire_for_classes``
accounts accordingly.

``rpc.rpc_call`` and ``onesided.remote_read`` are thin single-class wrappers
over this primitive; ``tx.run_transactions(fused=True)`` is the multi-class
user that cuts the OCC transaction from 5 exchange rounds to 3-4, and the
replicated commit adds its backup-write classes to the same round.

Public API: ``fused_round`` (the primitive), the class constructors
``read_class`` / ``rpc_class``, the handler applicators ``serial_apply`` /
``vector_apply``, and the transport-level ``ST_DROPPED`` status.  Invariant:
``fused=True`` schedules change ROUND COUNTS only — per-class replies,
overflow masks and delivered-request counts are bit-identical to running each
class in its own round (tests/test_tx_fused_equivalence.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import regions as rg
from repro.core.transport import (Transport, per_dest_wire, pick_replies,
                                  route_by_dest, wire_for_classes)
# Transport-level "request never delivered" status stamped into reply word 0
# of overflowed/parked RPC lanes (registered with every other status in
# core/wireproto.py; rpc.py re-exports it too).
from repro.core.wireproto import ST_DROPPED  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# Handler application (moved here from rpc.py so the scheduler has no import
# cycle; rpc.py re-exports both names).
# ---------------------------------------------------------------------------
def serial_apply(handler_fn, state, records, mask, reply_words: int):
    """Fold records through node state in a fixed serialization order.

    handler_fn(state, record (W,), valid) -> (state, reply (reply_words,))
    records: (S, C, W); mask: (S, C) -> replies (S, C, reply_words)
    """
    S, C, W = records.shape
    flat_r = records.reshape(S * C, W)
    flat_m = mask.reshape(S * C)

    def step(st, rm):
        rec, valid = rm
        st, rep = handler_fn(st, rec, valid)
        return st, rep

    state, flat_rep = lax.scan(step, state, (flat_r, flat_m))
    return state, flat_rep.reshape(S, C, reply_words)


def vector_apply(handler_fn, state, records, mask, reply_words: int):
    """handler_fn(state, records (S,C,W), mask) -> replies (S,C,reply_words).
    State is read-only on this path."""
    return state, handler_fn(state, records, mask)


# ---------------------------------------------------------------------------
# Traffic-class constructors
# ---------------------------------------------------------------------------
def read_class(dest, offsets, *, length: int, enabled=None,
               capacity: Optional[int] = None,
               mode: "rg.AddressMode | None" = None, page_tables=None):
    """One-sided READ class: owner action is translation + gather only."""
    return dict(kind="read", dest=dest,
                payload=offsets[..., None].astype(jnp.uint32),
                length=length, enabled=enabled, capacity=capacity,
                mode=mode, page_tables=page_tables)


def rpc_class(dest, records, handler, *, enabled=None,
              capacity: Optional[int] = None):
    """Write-based RPC class: owner runs ``handler`` over the sub-inbox."""
    return dict(kind="rpc", dest=dest, payload=records, handler=handler,
                enabled=enabled, capacity=capacity)


def _pad_words(x, width):
    pad = width - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def fused_round(t: Transport, state, classes: Sequence[dict], *,
                arena_key: str = "arena", nic=None, telemetry=None,
                phase: int = 0):
    """Run one fused exchange round carrying several traffic classes.

    state: pytree with leading node axis; read classes gather from
    ``state[arena_key]``.  Every class's ``dest`` is (N_local, B_k); rpc
    payloads are (N_local, B_k, W_k) uint32, read payloads are built from the
    (N_local, B_k) offsets by :func:`read_class`.

    Returns ``(state, results, stats)`` where ``results[k]`` is a
    ``(reply (N_local, B_k, R_k), overflow (N_local, B_k))`` pair aligned with
    ``classes`` and ``stats`` is ONE coalesced :class:`WireStats` for the
    whole round.  ``nic`` (an optional :class:`repro.core.nic.ConnTable`)
    stamps the modeled NIC-cache hit rate / connection-state penalty of the
    transport configuration into the stats (None = perfect NIC).
    Overflowed/parked rpc lanes carry ST_DROPPED in reply word 0
    (never aliasing ST_OK or a handler-returned status); overflowed/parked
    read lanes read back zeros.

    ``telemetry`` (an optional :class:`repro.core.telemetry.Recorder`)
    appends ONE flight-recorder event for this round — phase tag, class
    count, the WireStats snapshot, per-destination message/byte counts —
    into the recorder's TraceBuffer.  Recording only READS round values:
    ``telemetry=None`` (the default) is bit-identical.
    """
    n_dst = t.n_nodes
    specs = []
    for c in classes:
        dest = c["dest"]
        B_k = dest.shape[-1]
        cap = c.get("capacity")
        cap = B_k if cap is None else int(cap)
        if cap < 0:
            raise ValueError(f"per-destination capacity must be >= 0, got {cap}")
        payload = c["payload"]
        R_k = c["length"] if c["kind"] == "read" else c["handler"].reply_words
        en = c.get("enabled")
        if en is not None:
            buf, mask, pos, ovf = jax.vmap(
                lambda d, p, e: route_by_dest(d, p, n_dst, cap, e)
            )(dest, payload, en)
        else:
            buf, mask, pos, ovf = jax.vmap(
                lambda d, p: route_by_dest(d, p, n_dst, cap))(dest, payload)
        specs.append(dict(cls=c, cap=cap, W=payload.shape[-1], R=R_k,
                          buf=buf, mask=mask, pos=pos, ovf=ovf))

    c_total = sum(s["cap"] for s in specs)
    if c_total == 0:
        # nothing can be delivered this round: no exchange, no wire traffic
        stats = wire_for_classes([s["mask"] for s in specs],
                                 [s["W"] for s in specs],
                                 [s["R"] for s in specs], nic=nic)
        results = [(_dropped_replies(s), s["ovf"]) for s in specs]
        _record_round(telemetry, phase, specs, stats)
        return state, results, stats

    w_max = max(s["W"] for s in specs)
    r_max = max(s["R"] for s in specs)
    send = jnp.concatenate([_pad_words(s["buf"], w_max) for s in specs], axis=2)
    mask_all = jnp.concatenate([s["mask"] for s in specs], axis=2)
    inbox = t.exchange(send)            # (N_local, n_src, C_total, w_max)
    inbox_mask = t.exchange(mask_all)

    seg = []
    base = 0
    for s in specs:
        seg.append((base, base + s["cap"]))
        base += s["cap"]

    replies = [None] * len(specs)
    # 1) vector (read-only) handlers observe the round's pre-handler state
    for i, s in enumerate(specs):
        c = s["cls"]
        if c["kind"] == "rpc" and not c["handler"].serial and s["cap"] > 0:
            h = c["handler"]
            s0, s1 = seg[i]
            recs = inbox[:, :, s0:s1, :s["W"]]
            msk = inbox_mask[:, :, s0:s1]
            _, replies[i] = jax.vmap(
                lambda st, r, m, fn=h.fn, rw=h.reply_words:
                    vector_apply(fn, st, r, m, rw)
            )(state, recs, msk)
    # 2) serial (mutating) handlers fold through node state in class order
    for i, s in enumerate(specs):
        c = s["cls"]
        if c["kind"] == "rpc" and c["handler"].serial and s["cap"] > 0:
            h = c["handler"]
            s0, s1 = seg[i]
            recs = inbox[:, :, s0:s1, :s["W"]]
            msk = inbox_mask[:, :, s0:s1]
            state, replies[i] = jax.vmap(
                lambda st, r, m, fn=h.fn, rw=h.reply_words:
                    serial_apply(fn, st, r, m, rw)
            )(state, recs, msk)
    # 3) one-sided gathers run last, on the post-handler state
    arena = None
    for i, s in enumerate(specs):
        c = s["cls"]
        if c["kind"] == "read" and s["cap"] > 0:
            if arena is None:
                arena = state[arena_key]
            s0, s1 = seg[i]
            offs = inbox[:, :, s0:s1, 0]
            mode = c.get("mode")
            length = c["length"]
            if mode is not None and mode.kind == "paged":
                replies[i] = jax.vmap(
                    lambda a, pt, off, m=mode, ln=length:
                        rg.arena_read(a, off, ln, m, pt)
                )(arena, c["page_tables"], offs)
            else:
                replies[i] = jax.vmap(
                    lambda a, off, ln=length: rg.arena_read(a, off, ln)
                )(arena, offs)

    back = t.exchange(jnp.concatenate(
        [_pad_words(replies[i].astype(jnp.uint32), r_max)
         if replies[i] is not None
         else jnp.zeros(inbox.shape[:2] + (0, r_max), jnp.uint32)
         for i in range(len(specs))], axis=2))

    results = []
    for i, s in enumerate(specs):
        if s["cap"] == 0:
            results.append((_dropped_replies(s), s["ovf"]))
            continue
        s0, s1 = seg[i]
        out = jax.vmap(pick_replies)(
            back[:, :, s0:s1, :s["R"]], s["cls"]["dest"], s["pos"], s["ovf"])
        results.append((_finalize_reply(s, out), s["ovf"]))

    stats = wire_for_classes([s["mask"] for s in specs],
                             [s["W"] for s in specs],
                             [s["R"] for s in specs], nic=nic)
    _record_round(telemetry, phase, specs, stats)
    return state, results, stats


def _record_round(telemetry, phase, specs, stats):
    """Append this round's flight-recorder event (no-op when disabled)."""
    if telemetry is None:
        return
    pd_msgs, pd_bytes = per_dest_wire([s["mask"] for s in specs],
                                      [s["W"] for s in specs],
                                      [s["R"] for s in specs])
    telemetry.record(phase, stats, n_classes=len(specs),
                     per_dest_msgs=pd_msgs, per_dest_bytes=pd_bytes)


def _dropped_replies(s):
    """All-dropped reply block for a class that could deliver nothing."""
    shape = s["cls"]["dest"].shape + (s["R"],)
    out = jnp.zeros(shape, jnp.uint32)
    return _finalize_reply(s, out, all_dropped=True)


def _finalize_reply(s, out, all_dropped: bool = False):
    """Stamp ST_DROPPED into undelivered rpc lanes' status word (a zeroed
    reply's word 0 would alias ST_OK)."""
    c = s["cls"]
    if c["kind"] != "rpc":
        return out
    if all_dropped:
        no_reply = jnp.ones(c["dest"].shape, bool)
    else:
        # pos == cap is route_by_dest's "no live cell": capacity overflow,
        # disabled lanes, AND enabled lanes parked by an out-of-range dest
        # (placement's unreachable sentinel -1) — the last would otherwise
        # read back zeros and alias ST_OK
        no_reply = s["pos"] >= s["cap"]
    return out.at[..., 0].set(
        jnp.where(no_reply, jnp.uint32(ST_DROPPED), out[..., 0]))
