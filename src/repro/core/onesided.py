"""One-sided remote reads and writes (Storm §4.2, §5.1).

The defining property of a one-sided op is that the OWNER RUNS NO APPLICATION
LOGIC: the initiator names (node, offset, length) and the owner side is pure
data movement.  Here the owner-side computation is exactly an address
translation (flat or paged) plus a gather/scatter — the work an RDMA NIC does
in hardware — and nothing else.  Contrast with rpc.py, where the owner runs a
registered handler (pointer chasing, lock logic, ...).

All ops are batched: each node issues B lanes per round (the coroutine
pipeline).  One round = ONE network round trip for every lane in flight.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import regions as rg
from repro.core import roundsched as rs
from repro.core.transport import Transport, route_by_dest, wire_for


@partial(jax.named_call, name="storm_remote_read")
def remote_read(t: Transport, arenas, dest, offsets, *, length: int,
                capacity: Optional[int] = None,
                mode: rg.AddressMode | None = None, page_tables=None,
                enabled=None, nic=None, telemetry=None, phase: int = 0):
    """Batched one-sided READ — a single-class fused round (see
    roundsched.fused_round; the owner side is translation + gather ONLY).

    arenas:  (N_local, arena_words) uint32 — this shard's node states
    dest:    (N_local, B) int32  — target node of each lane
    offsets: (N_local, B) uint32 — word offset inside the target arena
    length:  static words per read (e.g. a 128B slot = 32 words)
    enabled: optional (N_local, B) bool — disabled lanes issue nothing and
             read back zeros (no capacity, no wire bytes).
    capacity: per-destination budget; ``None`` means B, 0 back-pressures
             every lane, negative values are rejected.

    Returns (data (N_local, B, length), overflow (N_local, B) bool, WireStats).
    """
    _, ((out, ovf),), stats = rs.fused_round(
        t, {"arena": arenas},
        [rs.read_class(dest, offsets, length=length, enabled=enabled,
                       capacity=capacity, mode=mode, page_tables=page_tables)],
        nic=nic, telemetry=telemetry, phase=phase)
    return out, ovf, stats


@partial(jax.named_call, name="storm_remote_write")
def remote_write(t: Transport, arenas, dest, offsets, values, *,
                 capacity: Optional[int] = None,
                 mode: rg.AddressMode | None = None, page_tables=None,
                 enabled=None, nic=None):
    """Batched one-sided WRITE (no reply payload — transport-level ack only).

    values: (N_local, B, L) uint32; enabled: optional (N_local, B) bool.
    Returns (new_arenas, overflow, WireStats).
    """
    B = dest.shape[-1]
    L = values.shape[-1]
    # capacity=0 must mean "deliver nothing", never silently "unbounded"
    cap = B if capacity is None else int(capacity)
    if cap < 0:
        raise ValueError(f"per-destination capacity must be >= 0, got {cap}")
    if enabled is None:
        enabled = jnp.ones(dest.shape, bool)
    payload = jnp.concatenate(
        [offsets[..., None].astype(jnp.uint32), values.astype(jnp.uint32)], axis=-1)
    # disabled lanes are parked at the routing layer: no cell, no capacity
    buf, mask, pos, ovf = jax.vmap(
        lambda d, p, e: route_by_dest(d, p, t.n_nodes, cap, e)
    )(dest, payload, enabled)
    inbox = t.exchange(buf)
    inbox_mask = t.exchange(mask)

    def owner_scatter(a, recs, msk, pt):
        off = recs[..., 0]
        val = recs[..., 1:]
        return rg.arena_write(a, off, val, mode=mode, page_table=pt,
                              enabled=msk)

    if mode is not None and mode.kind == "paged":
        arenas = jax.vmap(owner_scatter)(arenas, inbox, inbox_mask, page_tables)
    else:
        arenas = jax.vmap(lambda a, r, m: owner_scatter(a, r, m, None))(
            arenas, inbox, inbox_mask)
    stats = wire_for(mask, req_words=1 + L, reply_words=0, nic=nic)
    return arenas, ovf, stats
