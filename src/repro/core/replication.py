"""Primary-backup replication of the commit dataplane (toward the ROADMAP's
production north star; protocol shape follows FaRM-style COMMIT-BACKUP riding
Storm's fused exchange rounds — cf. Aguilera et al., *The Impact of RDMA on
Agreement*, on driving replication with one-sided-style primitives).

A record's PRIMARY copy lives on its hash-designated home node (Storm §5.5,
``hashtable.home_of``).  With a replication factor ``f`` > 0, every COMMIT
also installs the write set on ``f`` BACKUP nodes so the cluster survives the
loss of up to ``f`` nodes:

  * **Placement** is deterministic over the node ring:
    ``replica_of(primary, i) = (primary + i) mod n_nodes`` for i in 0..f
    (i = 0 is the primary itself).  Because the rotation is a bijection on
    destinations, each backup traffic class sends AT MOST as many records to
    any one destination as the commit class sends to the corresponding
    primary — so a commit round that fits the per-destination send budget
    fits its backup fan-out too (see ``tx.commit_or_abort``).
  * **Backup writes ride the commit round**: they are extra traffic classes
    in the SAME ``roundsched.fused_round`` as COMMIT/ABORT_UNLOCK, so ``f``>0
    adds ZERO exchange rounds to the fast path — only the commit round fans
    out wider (more (src, dst) pairs, priced by
    ``transport.wire_for_classes`` and the ``nic.ConnTable`` model).
  * **Byte-equal copies**: ``OP_BACKUP_WRITE`` installs the exact committed
    record image — key, committed version (predicted client-side from the
    LOCK reply as ``(lock_version | 1) + 1``), lock = 0, value.  Only the
    slot's ``next_ptr`` (per-table chain metadata) differs between copies.
  * **Never dropped silently**: a backup write dropped by send-queue
    back-pressure surfaces through the per-lane overflow mask and aborts the
    lane (cause: overflow), which ``txloop.tx_loop`` retries — exactly the
    path every other dropped request takes.  (Documented limitation: the
    primary copy of such a lane is already installed when the abort is
    reported — the retry reinstalls idempotently and converges; see
    ``tx.commit_or_abort``.)

Failure injection: ``kill_node`` marks nodes dead; ``failover_dest`` routes
each lane to the first LIVE replica on the ring; ``failover_lookup`` is the
reads-fail-over-to-backup path (one-sided probe of the backup bucket + RPC
fallback at the backup).  Requests whose every replica is dead are parked —
they are reported ``dead_route``, never silently served garbage.

Public API: ``ReplicaConfig`` (``replica_of``, ``backup_write_records``),
``all_alive`` / ``kill_node`` / ``failover_dest`` / ``failover_lookup``.
``f = 0`` (or ``rep=None``) is bit-identical to the unreplicated dataplane —
equivalence-tested in tests/test_replication.py and gated by
benchmarks/replication_cost.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import wireproto as W
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport


@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    """Replication factor + placement for one cluster (static, trace-time).

    f:         number of BACKUP copies per record (f + 1 copies total).
               f = 0 is bit-identical to the unreplicated dataplane.
    placement: optional override ``fn(primary, i, n_nodes) -> dest`` used by
               tests to build pathological placements (e.g. every backup on
               one node) — production placement is the ring rotation, whose
               bijectivity is what keeps the commit fan-out overflow-free.
    """
    n_nodes: int
    f: int = 0
    placement: Optional[Callable] = None

    def __post_init__(self):
        if not 0 <= self.f < self.n_nodes:
            raise ValueError(
                f"replication factor must satisfy 0 <= f < n_nodes "
                f"(got f={self.f}, n_nodes={self.n_nodes})")

    @property
    def n_copies(self) -> int:
        return self.f + 1

    def replica_of(self, primary, i: int):
        """Destination of copy ``i`` (0 = primary) of a record homed at
        ``primary``.  Ring rotation unless a test placement overrides it."""
        primary = jnp.asarray(primary, jnp.int32)
        if i == 0:
            return primary
        if self.placement is not None:
            return jnp.asarray(self.placement(primary, i, self.n_nodes),
                               jnp.int32)
        return (primary + jnp.int32(i)) % jnp.int32(self.n_nodes)


def committed_version(lock_version):
    """The version a commit installs, predicted from the LOCK reply.

    The LOCK reply's version word carries the slot's version at lock time:
    even for a found record, and the (even) base version a lock-insert
    placeholder was built on.  In both cases the owner commits
    ``(version_at_commit | 1) + 1``, which equals ``lock_version + 2`` — the
    backup write carries this value so every copy lands with the SAME
    version word as the primary."""
    return (jnp.asarray(lock_version, jnp.uint32) | jnp.uint32(1)) + jnp.uint32(1)


def backup_write_records(lock_ctx, write_values):
    """Build the OP_BACKUP_WRITE records for one commit round.

    lock_ctx: the lock-phase context (``tx._parse_lock_replies``) holding the
    flattened (N, B*Wr) write keys and lock-time versions.  write_values:
    reshapeable to (N, B*Wr, VALUE_WORDS).  The aux word carries the
    committed version so the backup installs the primary's exact image."""
    n, items = lock_ctx["key_lo"].shape
    return ht.make_record(
        W.OP_BACKUP_WRITE, lock_ctx["key_lo"], lock_ctx["key_hi"],
        aux=committed_version(lock_ctx["lock_ver"]),
        value=jnp.asarray(write_values).reshape(n, items, sl.VALUE_WORDS))


def btree_backup_records(lock_ctx, write_values):
    """OP_BT_BACKUP records for the ordered index's commit round: each
    committed (key, value) is upserted into the backup replica's FULL-RANGE
    backup tree (the key is outside the backup node's own partition under
    ring placement, so the handler routes it away from the primary fence
    chain — see btree.build_layout).  Replication of the ordered index is
    LOGICAL — the backup arena may pack records differently (its own split
    history) — unlike the hash table's byte-equal slot images; the aux word
    still carries the predicted committed leaf version for observability.
    Rides the commit fused round exactly like the hash-table backup classes
    (zero extra exchange rounds; see ``tx._bt_commit_or_abort``)."""
    from repro.core.datastructs import btree as bt
    n, items = lock_ctx["key_lo"].shape
    return bt.make_record(
        W.OP_BT_BACKUP, lock_ctx["key_lo"],
        jnp.zeros_like(lock_ctx["key_lo"]),
        aux=committed_version(lock_ctx["lock_ver"]),
        value=jnp.asarray(write_values).reshape(n, items, sl.VALUE_WORDS))


# ---------------------------------------------------------------------------
# Failure injection + read fail-over
# ---------------------------------------------------------------------------
def all_alive(n_nodes: int):
    """Fresh liveness mask: every node up."""
    return jnp.ones((n_nodes,), bool)


def kill_node(alive, node):
    """Mark ``node`` (an index or an index array) dead.  Dead nodes receive
    no requests from the failover paths; killing is idempotent."""
    return alive.at[jnp.asarray(node)].set(False)


def failover_dest(rep: ReplicaConfig, alive, primary):
    """Route each lane to the FIRST live replica on the ring.

    Thin policy over the placement subsystem: the ring placement is expressed
    as a ``PlacementTable`` (``placement.table_from_replica``) and the scan
    itself is THE one first-live-copy rule, ``placement.live_dest`` — there
    is no second failover implementation.  primary: (...,) int32.  Returns
    (dest, reachable); unreachable lanes (every copy dead) carry the parked
    sentinel dest = -1 and must not be routed."""
    from repro.core import placement as pl
    table = pl.table_from_replica(rep, alive)
    return pl.live_dest(table, primary)


def failover_lookup(t: Transport, state, key_lo, key_hi,
                    cfg: ht.HashTableConfig, layout, rep: ReplicaConfig,
                    alive, *, capacity: Optional[int] = None, enabled=None,
                    nic=None):
    """Reads fail over to the backup: the one-two-sided hybrid lookup issued
    at each key's first LIVE replica instead of its (possibly dead) primary.

    Thin wrapper over the generic ``placement.failover_lookup`` (which also
    serves the btree's backup tree — the hash-only special case this module
    used to carry is gone).  The bucket half of the hash is node-independent
    (``hashtable.home_of``), so the backup copy lives in the SAME bucket of
    the replica's table and the probe is byte-for-byte the ordinary hybrid
    lookup, just routed through the table.  Returns a dict with found /
    value / version / node / slot_idx / overflow / dead_route / wire.
    ``dead_route`` lanes (no live replica) issue nothing, report
    found=False."""
    from repro.core import placement as pl
    table = pl.table_from_replica(rep, alive)
    return pl.failover_lookup(t, state, cfg, layout, table, key_lo, key_hi,
                              ds=ht, capacity=capacity, enabled=enabled,
                              nic=nic)
