"""MICA-style inline slot codec (Storm §5.5).

Storm achieves zero-copy by inlining all per-item metadata in the slot that is
fetched by a single one-sided read: key, lock and version live next to the
value.  A slot is SLOT_WORDS uint32 words (= 128 bytes, the paper's transfer
unit: "Each data transfer, including the application-level and RPC-level
headers, is 128 bytes in size").

Layout (uint32 words):
  [0] key_lo        [1] key_hi
  [2] version       (seqlock: even = stable, odd = write in progress)
  [3] lock          (0 = free, owner_tag+1 otherwise)
  [4] next_ptr      (global slot index of overflow-chain successor; NULL_PTR = end)
  [5..] value       (VALUE_WORDS words = 108 B payload)

Everything here is branch-free and vmap-friendly: slots travel as (..., 32)
uint32 arrays, exactly the byte image a one-sided read would return.
"""
from __future__ import annotations

import jax.numpy as jnp

SLOT_WORDS = 32
SLOT_BYTES = SLOT_WORDS * 4          # 128 B, the paper's item size
KEY_LO, KEY_HI, VERSION, LOCK, NEXT_PTR, VALUE0 = 0, 1, 2, 3, 4, 5
VALUE_WORDS = SLOT_WORDS - VALUE0    # 27 words = 108 B
NULL_PTR = jnp.uint32(0xFFFFFFFF)
EMPTY_KEY = jnp.uint32(0xFFFFFFFF)   # key_lo of an empty slot


# Built once at import (never under a trace): callers memoize closures over
# this value (e.g. the btree handler cache), and a slot image minted inside a
# lax.scan trace would leak that trace into every later caller.
_EMPTY_SLOT = (jnp.zeros((SLOT_WORDS,), jnp.uint32)
               .at[KEY_LO].set(EMPTY_KEY)
               .at[NEXT_PTR].set(NULL_PTR))


def make_empty_slot() -> jnp.ndarray:
    return _EMPTY_SLOT


def pack_slot(key_lo, key_hi, version, lock, next_ptr, value) -> jnp.ndarray:
    """value: (..., VALUE_WORDS) uint32. Returns (..., SLOT_WORDS)."""
    head = jnp.stack(
        [jnp.asarray(key_lo, jnp.uint32),
         jnp.asarray(key_hi, jnp.uint32),
         jnp.asarray(version, jnp.uint32),
         jnp.asarray(lock, jnp.uint32),
         jnp.asarray(next_ptr, jnp.uint32)], axis=-1)
    return jnp.concatenate([head, jnp.asarray(value, jnp.uint32)], axis=-1)


def slot_key_lo(slot):   return slot[..., KEY_LO]
def slot_key_hi(slot):   return slot[..., KEY_HI]
def slot_version(slot):  return slot[..., VERSION]
def slot_lock(slot):     return slot[..., LOCK]
def slot_next(slot):     return slot[..., NEXT_PTR]
def slot_value(slot):    return slot[..., VALUE0:]


def slot_matches(slot, key_lo, key_hi):
    """Key match & stable (even version) & unlocked — the `lookup_end`
    validity predicate for a one-sided read (Storm Algorithm 1, line 7)."""
    return (
        (slot_key_lo(slot) == key_lo)
        & (slot_key_hi(slot) == key_hi)
        & (slot_version(slot) % 2 == 0)
        & (slot_lock(slot) == 0)
    )


def slot_is_empty(slot):
    return slot_key_lo(slot) == EMPTY_KEY


# ---------------------------------------------------------------------------
# Key hashing: 64-bit splittable mix done in uint32 lanes (JAX x64 stays off).
# node id and bucket id come from independent halves of the mix so the
# distribution across nodes is independent from the distribution over buckets.
# ---------------------------------------------------------------------------
_M1 = jnp.uint32(0x85EBCA6B)
_M2 = jnp.uint32(0xC2B2AE35)
_GOLDEN = jnp.uint32(0x9E3779B9)


def _mix32(x):
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_key(key_lo, key_hi):
    """Returns (h_node, h_bucket) — two decorrelated 32-bit hashes."""
    a = _mix32(jnp.asarray(key_lo, jnp.uint32))
    b = _mix32(jnp.asarray(key_hi, jnp.uint32) + _GOLDEN)
    h1 = _mix32(a + b * _M1)
    h2 = _mix32(b + a * _M2 + _GOLDEN)
    return h1, h2
