"""Distributed MICA-style hash table (Storm §5.5) speaking the Storm
data-structure interface (Table 3): ``lookup_start`` / ``lookup_end`` /
``rpc_handler``.

Layout per node (one contiguous arena — §5.1):

  [ slots region : (n_buckets * bucket_width + n_overflow) slots of 128 B ]
  [ alloc        : 1 word — bump allocator for overflow slots              ]
  [ scratch      : 1 word — write sink for masked lanes                    ]

A bucket is `bucket_width` consecutive slots.  When a bucket fills up,
colliding items go to overflow slots linked from the LAST bucket slot's
next_ptr (the paper: "Colliding items are kept in a linked list when the
bucket capacity is exceeded") — the pointer chase that motivates the
one-two-sided hybrid.

Knobs reproduce the paper's configurations:
  * bucket_width=1 + low occupancy  -> Storm(oversub): 128 B one-sided reads
  * bucket_width=8                  -> FaRM emulation: 8x larger reads,
                                       no chase in the common case
  * client address cache            -> Storm(perfect) / DrTM+H-style caching
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import placement as pl
from repro.core import regions as rg
from repro.core import rpc as R
from repro.core import wireproto as W
from repro.core import slots as sl


@dataclasses.dataclass(frozen=True)
class HashTableConfig:
    n_nodes: int
    n_buckets: int                 # per node, power of two
    bucket_width: int = 1
    n_overflow: int = 256          # per node
    max_chain: int = 8             # bounded chain walk in the handler
    cache_slots: int = 0           # client-side address cache (0 = off)

    @property
    def n_bucket_slots(self) -> int:
        return self.n_buckets * self.bucket_width

    @property
    def n_slots(self) -> int:
        return self.n_bucket_slots + self.n_overflow

    @property
    def max_probe(self) -> int:
        return self.bucket_width + self.max_chain

    # record: [op, key_lo, key_hi, aux, value...]
    @property
    def record_words(self) -> int:
        return 4 + sl.VALUE_WORDS

    # reply: [status, aux (slot idx), version, value...]
    @property
    def reply_words(self) -> int:
        return 3 + sl.VALUE_WORDS


def build_layout(cfg: HashTableConfig) -> rg.RegionTable:
    tbl = rg.RegionTable()
    tbl.register("slots", cfg.n_slots * sl.SLOT_WORDS)
    tbl.register("alloc", 1)
    # coordinator-published placement table (core/placement.py): epoch, the
    # per-partition copy rows, and the liveness bitmap — refreshed by clients
    # with ONE one-sided read, consulted by the handler's owner check
    tbl.register("routing", pl.routing_words(cfg.n_nodes))
    tbl.register("scratch", 1)     # must stay LAST (write sink)
    return tbl


def init_node_state(cfg: HashTableConfig, layout: rg.RegionTable):
    """Arena with every slot formatted empty and the epoch-0 identity
    placement table published (node p owns partition p — what keeps the
    placement-routed fast path bit-identical to static partition math)."""
    arena = rg.make_arena(layout)
    slots_r = layout["slots"]
    empty = jnp.tile(sl.make_empty_slot(), (cfg.n_slots,))
    arena = lax.dynamic_update_slice(arena, empty, (slots_r.base,))
    arena = lax.dynamic_update_slice(
        arena, pl.identity_region_image(cfg.n_nodes),
        (layout["routing"].base,))
    return {"arena": arena}


def init_cluster_state(cfg: HashTableConfig):
    layout = build_layout(cfg)
    one = init_node_state(cfg, layout)
    st = jax.tree.map(
        lambda x: jnp.tile(x[None], (cfg.n_nodes,) + (1,) * x.ndim), one)
    rb = layout["routing"].base
    st["arena"] = st["arena"].at[:, rb + pl.SELF_WORD].set(
        jnp.arange(cfg.n_nodes, dtype=jnp.uint32))
    return st


# ---------------------------------------------------------------------------
# Addressing helpers
# ---------------------------------------------------------------------------
def home_of(cfg: HashTableConfig, key_lo, key_hi):
    """(node, bucket) for a key."""
    h1, h2 = sl.hash_key(key_lo, key_hi)
    node = (h1 % jnp.uint32(cfg.n_nodes)).astype(jnp.int32)
    bucket = h2 % jnp.uint32(cfg.n_buckets)
    return node, bucket


def part_of(cfg: HashTableConfig, key_lo, key_hi):
    """The key's PARTITION (generic placement interface).  Partition ids
    coincide with home nodes under the identity table; placement maps them
    to whatever node currently owns them."""
    node, _ = home_of(cfg, key_lo, key_hi)
    return node


def bucket_offset(cfg: HashTableConfig, layout: rg.RegionTable, bucket):
    base = layout["slots"].base
    return jnp.uint32(base) + bucket.astype(jnp.uint32) * jnp.uint32(
        cfg.bucket_width * sl.SLOT_WORDS)


def slot_idx_offset(layout: rg.RegionTable, slot_idx):
    return rg.slot_offset(layout["slots"], slot_idx)


# ---------------------------------------------------------------------------
# Client side: lookup_start / lookup_end (Storm Table 3)
# ---------------------------------------------------------------------------
def lookup_start(cfg: HashTableConfig, layout: rg.RegionTable, key_lo, key_hi,
                 cache=None, ptable=None):
    """Client-side metadata lookup: where *might* the item live?

    Returns (node, offset, read_slots, cache_hit).  With an address cache
    (Storm(perfect) / DrTM+H), a hit yields the EXACT slot (1-slot read);
    otherwise the home bucket (bucket_width-slot read).

    ptable: optional placement.PlacementTable — reads route to the
    partition's first LIVE copy (owner when everything is up, so the
    epoch-stable path is bit-identical; a backup after a failure — the
    bucket half of the hash is node-independent, so the copy lives in the
    SAME bucket of the replica's table).  No live copy routes to -1, which
    the transport parks.
    """
    node, bucket = home_of(cfg, key_lo, key_hi)
    if ptable is not None:
        node, _ = pl.live_dest(ptable, node)
    off = bucket_offset(cfg, layout, bucket)
    hit = jnp.zeros(jnp.shape(key_lo), bool)
    if cache is not None and cfg.cache_slots > 0:
        cidx = (sl._mix32(key_lo) ^ key_hi) % jnp.uint32(cfg.cache_slots)
        tag_ok = ((cache["key_lo"][cidx] == key_lo)
                  & (cache["key_hi"][cidx] == key_hi))
        cnode = cache["node"][cidx].astype(jnp.int32)
        coff = slot_idx_offset(layout, cache["slot"][cidx])
        hit = tag_ok
        node = jnp.where(hit, cnode, node)
        off = jnp.where(hit, coff, off)
    return node, off, hit


def uses_probe_cache(cfg: HashTableConfig) -> bool:
    """Whether ``hybrid.onesided_probe`` should vmap lookup_start over a
    per-client cache (part of the generic data-structure interface)."""
    return cfg.cache_slots > 0


def probe_words(cfg: HashTableConfig) -> int:
    """Words fetched by one one-sided probe (generic interface)."""
    return cfg.bucket_width * sl.SLOT_WORDS


def lookup_records(cfg: HashTableConfig, key_lo, key_hi):
    """Request records for the point-lookup RPC fallback (generic
    interface)."""
    return make_record(W.OP_LOOKUP, key_lo, key_hi)


def probe_end(cfg: HashTableConfig, layout: rg.RegionTable, buf, key_lo,
              key_hi, off, hit):
    """Generic-interface wrapper over :func:`lookup_end`: decode a one-sided
    probe into (found, value, version, slot_idx, resolved).

    For the hash table ``resolved == found``: a miss may sit on an unread
    overflow chain, so only a validated HIT makes the RPC fallback
    unnecessary (the ordered index differs — see btree.probe_end)."""
    success, value, local_idx = lookup_end(cfg, buf, key_lo, key_hi,
                                           cache_hit=hit)
    slots_v = buf.reshape(buf.shape[:-1] + (cfg.bucket_width, sl.SLOT_WORDS))
    version = jnp.take_along_axis(
        slots_v[..., sl.VERSION], local_idx[..., None].astype(jnp.int32),
        axis=-1)[..., 0]
    # global slot idx of the hit.  A cache hit reads the exact cached slot
    # and lookup_end only accepts a match at window position 0, so the
    # matched slot IS the cached one — never cached_idx + local_idx, which
    # could cross a bucket (or region) boundary when bucket_width > 1.
    _, bucket = home_of(cfg, key_lo, key_hi)
    base_idx = bucket * jnp.uint32(cfg.bucket_width) + local_idx
    cached_idx = ((jnp.asarray(off, jnp.uint32)
                   - jnp.uint32(layout["slots"].base))
                  // jnp.uint32(sl.SLOT_WORDS))
    slot_idx = jnp.where(hit, cached_idx, base_idx)
    return dict(found=success, value=value, version=version,
                slot_idx=slot_idx, resolved=success)


def lookup_end(cfg: HashTableConfig, buf, key_lo, key_hi, cache_hit=None):
    """Validate a one-sided read result (Storm Algorithm 1 line 7).

    buf: (..., read_slots * SLOT_WORDS).  Returns (success, value, local_idx)
    where local_idx is the matching slot's index within the read (for address
    caching).

    cache_hit: optional (...,) bool.  A cache-hit read targets ONE exact slot;
    when bucket_width > 1 the (static-length) read window still spans
    bucket_width slots, which belong to a *different* bucket — or, for a
    cached overflow slot near the arena end, to clamped out-of-region garbage.
    For hit lanes only window position 0 (the cached slot itself) may match;
    a stale cache entry then falls through to the RPC path, which re-learns
    the address.
    """
    shp = buf.shape[:-1]
    width = buf.shape[-1] // sl.SLOT_WORDS
    slots_ = buf.reshape(shp + (width, sl.SLOT_WORDS))
    m = sl.slot_matches(slots_, key_lo[..., None], key_hi[..., None])
    if cache_hit is not None:
        exact_only = (jnp.arange(width) == 0) | ~cache_hit[..., None]
        m = m & exact_only
    success = jnp.any(m, axis=-1)
    local_idx = jnp.argmax(m, axis=-1)
    value = jnp.take_along_axis(
        sl.slot_value(slots_), local_idx[..., None, None], axis=-2
    )[..., 0, :]
    return success, value, local_idx.astype(jnp.uint32)


def cache_update(cfg: HashTableConfig, cache, key_lo, key_hi, node, slot_idx,
                 valid):
    """lookup_end's caching duty: remember exact addresses learned from RPC
    replies (or validated reads) for future one-sided reads."""
    if cache is None or cfg.cache_slots == 0:
        return cache
    cidx = (sl._mix32(key_lo) ^ key_hi) % jnp.uint32(cfg.cache_slots)
    def upd(arr, val):
        cur = arr[cidx]
        return arr.at[cidx].set(jnp.where(valid, val.astype(arr.dtype), cur))
    return {
        "key_lo": upd(cache["key_lo"], key_lo),
        "key_hi": upd(cache["key_hi"], key_hi),
        "node": upd(cache["node"], node.astype(jnp.uint32)),
        "slot": upd(cache["slot"], slot_idx),
    }


def init_cache(cfg: HashTableConfig):
    if cfg.cache_slots == 0:
        return None
    n = cfg.cache_slots
    return {
        "key_lo": jnp.full((n,), sl.EMPTY_KEY, jnp.uint32),
        "key_hi": jnp.zeros((n,), jnp.uint32),
        "node": jnp.zeros((n,), jnp.uint32),
        "slot": jnp.zeros((n,), jnp.uint32),
    }


# ---------------------------------------------------------------------------
# Owner side: the walk + rpc_handler
# ---------------------------------------------------------------------------
def _read_slot(cfg, layout, arena, slot_idx):
    off = slot_idx_offset(layout, slot_idx)
    return lax.dynamic_slice(arena, (off.astype(jnp.int32),), (sl.SLOT_WORDS,))


def _write_slot(cfg, layout, arena, slot_idx, slot, enabled):
    off = slot_idx_offset(layout, slot_idx).astype(jnp.int32)
    cur = lax.dynamic_slice(arena, (off,), (sl.SLOT_WORDS,))
    new = jnp.where(enabled, slot, cur)
    return lax.dynamic_update_slice(arena, new, (off,))


def find(cfg: HashTableConfig, layout: rg.RegionTable, arena, key_lo, key_hi):
    """Bounded bucket + chain walk.  Returns a dict with:
    found, slot_idx, slot, tail_idx (last probed chain slot),
    free_idx / has_free (first empty slot anywhere on the probe path —
    bucket slot OR linked chain slot, so deleted slots are reclaimed by
    inserts instead of the bump allocator growing forever), and
    free_next / free_ver (that slot's next_ptr and version, which a reuse
    MUST preserve: the next_ptr may carry an overflow chain, and the version
    must stay monotone so a re-inserted key cannot ABA past a validator).
    """
    _, bucket = home_of(cfg, key_lo, key_hi)
    first = (bucket * jnp.uint32(cfg.bucket_width)).astype(jnp.uint32)

    def body(step, st):
        (cur, found, fidx, fslot, tail, free_idx, free_next, free_ver,
         has_free, alive) = st
        slot = _read_slot(cfg, layout, arena, cur)
        is_match = sl.slot_key_lo(slot) == key_lo
        is_match &= sl.slot_key_hi(slot) == key_hi
        is_empty = sl.slot_is_empty(slot)
        new_found = found | (is_match & alive)
        fidx = jnp.where(is_match & alive & ~found, cur, fidx)
        fslot = jnp.where(is_match & alive & ~found, slot, fslot)
        take_free = is_empty & alive & ~has_free
        free_idx = jnp.where(take_free, cur, free_idx)
        free_next = jnp.where(take_free, sl.slot_next(slot), free_next)
        free_ver = jnp.where(take_free, sl.slot_version(slot), free_ver)
        has_free_new = has_free | (is_empty & alive)
        tail = jnp.where(alive, cur, tail)
        nxt = jnp.where(step < cfg.bucket_width - 1, cur + 1, sl.slot_next(slot))
        alive_next = alive & (nxt != sl.NULL_PTR)
        return (jnp.where(alive_next, nxt, cur), new_found, fidx, fslot,
                tail, free_idx, free_next, free_ver, has_free_new, alive_next)

    init = (first, jnp.asarray(False), jnp.uint32(0), jnp.zeros((sl.SLOT_WORDS,), jnp.uint32),
            first, jnp.uint32(0), sl.NULL_PTR, jnp.uint32(0),
            jnp.asarray(False), jnp.asarray(True))
    (cur, found, fidx, fslot, tail, free_idx, free_next, free_ver, has_free,
     _) = lax.fori_loop(0, cfg.max_probe, body, init)
    return dict(found=found, slot_idx=fidx, slot=fslot, tail_idx=tail,
                free_idx=free_idx, free_next=free_next, free_ver=free_ver,
                has_free=has_free)


def make_rpc_handler(cfg: HashTableConfig, layout: rg.RegionTable) -> R.Handler:
    """The serial (mutating-capable) rpc_handler.  Record layout:
    [op, key_lo, key_hi, aux, value...]; reply [status, aux, value...].
    COMMIT_UNLOCK/ABORT_UNLOCK records repurpose the key_lo word to carry the
    caller's lock tag (the slot is addressed directly by aux = slot idx).

    Lock-class ops (LOCK / INSERT / UPDATE / DELETE) are OWNER-CHECKED
    against the published placement table: if this node no longer owns the
    key's partition the op is refused with ST_WRONG_EPOCH and writes
    nothing — the stale-routed lane aborts (cause ``stale_route``),
    refreshes its table and retries.  COMMIT/ABORT are deliberately
    unchecked (a granted lock must always be releasable wherever it was
    granted), as are reads (version-validated) and OP_BACKUP_WRITE
    (driver/commit-directed).  OP_PL_INSTALL updates this node's routing
    region (one partition row + epoch + liveness per record)."""
    alloc_off = layout["alloc"].base
    ovf_base = cfg.n_bucket_slots
    rb = layout["routing"].base
    alive_off = rb + pl.COPIES_WORD + cfg.n_nodes * pl.MAX_COPIES
    aw = pl.alive_words(cfg.n_nodes)

    def fn(state, rec, valid):
        arena = state["arena"]
        op = rec[0]
        key_lo, key_hi, aux = rec[1], rec[2], rec[3]
        val = rec[4:4 + sl.VALUE_WORDS]
        f = find(cfg, layout, arena, key_lo, key_hi)
        slot = f["slot"]
        alloc = arena[alloc_off]

        status = jnp.uint32(W.ST_BAD_OP)
        out_aux = jnp.uint32(0)
        out_ver = jnp.uint32(0)
        out_val = jnp.zeros((sl.VALUE_WORDS,), jnp.uint32)
        write_idx = jnp.uint32(0)
        write_slot = jnp.zeros((sl.SLOT_WORDS,), jnp.uint32)
        do_write = jnp.asarray(False)
        link_tail = jnp.asarray(False)       # also update tail slot's next_ptr
        bump_alloc = jnp.asarray(False)

        is_nop = op == W.OP_NOP
        # ---- LOOKUP ------------------------------------------------------
        is_lookup = op == W.OP_LOOKUP
        lk_ok = f["found"] & (sl.slot_version(slot) % 2 == 0)
        status = jnp.where(is_lookup,
                           jnp.where(lk_ok, W.ST_OK, W.ST_NOT_FOUND).astype(jnp.uint32),
                           status)
        out_aux = jnp.where(is_lookup, f["slot_idx"], out_aux)
        out_ver = jnp.where(is_lookup, sl.slot_version(slot), out_ver)
        out_val = jnp.where(is_lookup & lk_ok, sl.slot_value(slot), out_val)

        # ---- INSERT / UPDATE (unconditional write API, outside tx) --------
        is_ins = op == W.OP_INSERT
        is_upd = op == W.OP_UPDATE
        locked_other = sl.slot_lock(slot) != 0
        # update in place when found & unlocked
        upd_ok = f["found"] & ~locked_other
        new_ver = sl.slot_version(slot) + 2
        upd_slot = sl.pack_slot(key_lo, key_hi, new_ver, 0, sl.slot_next(slot), val)
        # fresh insert: reuse the first empty slot on the probe path (bucket
        # OR chain — deleted slots are reclaimed), else overflow alloc + link.
        # A reused slot keeps its next_ptr (it may carry the overflow chain a
        # delete left behind — writing NULL_PTR would sever the chain and
        # orphan every key hanging off it) and its version (the delete
        # already bumped it; resetting to 0 would let a deleted-then-
        # re-inserted key ABA past a concurrent validator).
        can_ovf = alloc < jnp.uint32(cfg.n_overflow)
        reuse = f["has_free"]
        ins_idx = jnp.where(reuse, f["free_idx"], ovf_base + alloc)
        ins_possible = reuse | can_ovf
        ins_next = jnp.where(reuse, f["free_next"], sl.NULL_PTR)
        ins_ver = jnp.where(reuse, f["free_ver"], jnp.uint32(0))
        ins_slot = sl.pack_slot(key_lo, key_hi, ins_ver, 0, ins_next, val)

        ins_found = is_ins & f["found"]
        ins_fresh = is_ins & ~f["found"]
        status = jnp.where(is_ins, jnp.where(
            f["found"], jnp.where(upd_ok, W.ST_OK, W.ST_LOCK_FAIL),
            jnp.where(ins_possible, W.ST_OK, W.ST_NO_SPACE)).astype(jnp.uint32), status)
        status = jnp.where(is_upd, jnp.where(
            f["found"], jnp.where(upd_ok, W.ST_OK, W.ST_LOCK_FAIL),
            W.ST_NOT_FOUND).astype(jnp.uint32), status)

        wr_upd = (ins_found | (is_upd & f["found"])) & upd_ok
        wr_ins = ins_fresh & ins_possible
        do_write = do_write | wr_upd | wr_ins
        write_idx = jnp.where(wr_upd, f["slot_idx"], write_idx)
        write_slot = jnp.where(wr_upd, upd_slot, write_slot)
        write_idx = jnp.where(wr_ins, ins_idx, write_idx)
        write_slot = jnp.where(wr_ins, ins_slot, write_slot)
        link_tail = link_tail | (wr_ins & ~f["has_free"])
        bump_alloc = bump_alloc | (wr_ins & ~f["has_free"])
        out_aux = jnp.where(wr_upd | wr_ins, write_idx, out_aux)

        # ---- DELETE --------------------------------------------------------
        is_del = op == W.OP_DELETE
        del_ok = f["found"] & ~locked_other
        del_slot = slot.at[sl.KEY_LO].set(sl.EMPTY_KEY)
        del_slot = del_slot.at[sl.VERSION].set(sl.slot_version(slot) + 2)
        status = jnp.where(is_del, jnp.where(
            f["found"], jnp.where(del_ok, W.ST_OK, W.ST_LOCK_FAIL),
            W.ST_NOT_FOUND).astype(jnp.uint32), status)
        do_write = do_write | (is_del & del_ok)
        write_idx = jnp.where(is_del & del_ok, f["slot_idx"], write_idx)
        write_slot = jnp.where(is_del & del_ok, del_slot, write_slot)

        # ---- LOCK (tx execution phase) ------------------------------------
        is_lock = op == W.OP_LOCK
        tag = aux  # caller-unique nonzero tag
        lock_free = sl.slot_lock(slot) == 0
        lock_ok = f["found"] & lock_free
        lk_slot = slot.at[sl.LOCK].set(tag)
        # lock-insert for new keys: a locked, odd-version placeholder.  Like
        # ins_slot it preserves a reused slot's next_ptr and builds its odd
        # version on top of the slot's current (even) one.
        ph_slot = sl.pack_slot(key_lo, key_hi, ins_ver + jnp.uint32(1), tag,
                               ins_next,
                               jnp.zeros((sl.VALUE_WORDS,), jnp.uint32))
        lock_ins = is_lock & ~f["found"] & ins_possible
        status = jnp.where(is_lock, jnp.where(
            f["found"], jnp.where(lock_free, W.ST_OK, W.ST_LOCK_FAIL),
            jnp.where(ins_possible, W.ST_OK, W.ST_NO_SPACE)).astype(jnp.uint32), status)
        do_write = do_write | (is_lock & lock_ok) | lock_ins
        write_idx = jnp.where(is_lock & lock_ok, f["slot_idx"], write_idx)
        write_slot = jnp.where(is_lock & lock_ok, lk_slot, write_slot)
        write_idx = jnp.where(lock_ins, ins_idx, write_idx)
        write_slot = jnp.where(lock_ins, ph_slot, write_slot)
        link_tail = link_tail | (lock_ins & ~f["has_free"])
        bump_alloc = bump_alloc | (lock_ins & ~f["has_free"])
        out_aux = jnp.where(is_lock & (lock_ok | lock_ins),
                            jnp.where(lock_ok, f["slot_idx"], ins_idx), out_aux)
        # version + current value at lock time (read-for-update, Fig. 3).
        # Lock-inserts report the (even) base version the placeholder was
        # built on, so the client can predict the committed version of EVERY
        # lock it holds as (version | 1) + 1 — what prices the byte-identical
        # backup install (replication.committed_version).
        out_ver = jnp.where(is_lock,
                            jnp.where(f["found"], sl.slot_version(slot), ins_ver),
                            out_ver)
        out_val = jnp.where(is_lock & lock_ok, sl.slot_value(slot), out_val)

        # ---- COMMIT_UNLOCK / ABORT_UNLOCK (direct slot addressing) ---------
        # record layout here: [op, lock_tag, key_hi, slot_idx, value...] —
        # the key_lo word carries the caller's lock tag instead of a key (the
        # slot is addressed directly via aux, so no key walk is needed).
        is_commit = op == W.OP_COMMIT_UNLOCK
        is_abort = op == W.OP_ABORT_UNLOCK
        tgt = aux  # slot idx from the LOCK reply
        unlock_tag = key_lo
        tslot = _read_slot(cfg, layout, arena, tgt)
        # ownership requires the EXACT tag that acquired the lock: a retried
        # or misrouted unlock must never release another lane's lock
        own = (sl.slot_lock(tslot) != 0) & (sl.slot_lock(tslot) == unlock_tag)
        cm_ver = (sl.slot_version(tslot) | jnp.uint32(1)) + jnp.uint32(1)  # -> even, bumped
        cm_slot = sl.pack_slot(sl.slot_key_lo(tslot), sl.slot_key_hi(tslot),
                               cm_ver, 0, sl.slot_next(tslot), val)
        was_placeholder = sl.slot_version(tslot) % 2 == 1
        ab_slot = jnp.where(was_placeholder,
                            tslot.at[sl.KEY_LO].set(sl.EMPTY_KEY).at[sl.LOCK].set(0)
                                 .at[sl.VERSION].set(cm_ver),
                            tslot.at[sl.LOCK].set(0))
        status = jnp.where(is_commit | is_abort,
                           jnp.where(own, W.ST_OK, W.ST_LOCK_FAIL).astype(jnp.uint32),
                           status)
        do_write = do_write | ((is_commit | is_abort) & own)
        write_idx = jnp.where((is_commit | is_abort) & own, tgt, write_idx)
        write_slot = jnp.where(is_commit & own, cm_slot, write_slot)
        write_slot = jnp.where(is_abort & own, ab_slot, write_slot)

        # ---- READ_VERSION ---------------------------------------------------
        is_rdv = op == W.OP_READ_VERSION
        vslot = _read_slot(cfg, layout, arena, aux)
        status = jnp.where(is_rdv, jnp.uint32(W.ST_OK), status)
        out_aux = jnp.where(is_rdv, aux, out_aux)
        out_ver = jnp.where(is_rdv, sl.slot_version(vslot), out_ver)

        # ---- BACKUP_WRITE (primary-backup replication) ---------------------
        # record: [op, key_lo, key_hi, aux = committed version, value...].
        # Installs the primary's exact committed image — key, version, lock=0,
        # value — on THIS node's table; only next_ptr (per-table chain
        # metadata) is local.  The version comes from the committing client
        # (replication.committed_version), so every copy of a record carries
        # the SAME version word and reads can fail over without OCC anomalies
        # (a stale copy can never alias the current one: key+version differ).
        # Backup copies are never LOCKed (locks target the primary), so there
        # is no locked_other arm here.
        is_bkw = op == W.OP_BACKUP_WRITE
        bk_upd = sl.pack_slot(key_lo, key_hi, aux, 0, sl.slot_next(slot), val)
        bk_ins = sl.pack_slot(key_lo, key_hi, aux, 0, ins_next, val)
        status = jnp.where(is_bkw, jnp.where(
            f["found"] | ins_possible, W.ST_OK, W.ST_NO_SPACE).astype(jnp.uint32),
            status)
        wr_bk_upd = is_bkw & f["found"]
        wr_bk_ins = is_bkw & ~f["found"] & ins_possible
        do_write = do_write | wr_bk_upd | wr_bk_ins
        write_idx = jnp.where(wr_bk_upd, f["slot_idx"], write_idx)
        write_slot = jnp.where(wr_bk_upd, bk_upd, write_slot)
        write_idx = jnp.where(wr_bk_ins, ins_idx, write_idx)
        write_slot = jnp.where(wr_bk_ins, bk_ins, write_slot)
        link_tail = link_tail | (wr_bk_ins & ~f["has_free"])
        bump_alloc = bump_alloc | (wr_bk_ins & ~f["has_free"])
        out_aux = jnp.where(wr_bk_upd | wr_bk_ins, write_idx, out_aux)
        out_ver = jnp.where(is_bkw, aux, out_ver)

        # ---- owner check (placement epoch validation) ----------------------
        # lock-class ops only: a node that lost the key's partition since the
        # client cached its table refuses the op instead of mutating state it
        # no longer owns.  part = static hash math; owner = column 0 of this
        # node's PUBLISHED routing region (updated by OP_PL_INSTALL).
        checked = is_ins | is_upd | is_del | is_lock
        h1_, _ = sl.hash_key(key_lo, key_hi)
        part_ = h1_ % jnp.uint32(cfg.n_nodes)
        owner = arena[jnp.uint32(rb + pl.COPIES_WORD)
                      + part_ * jnp.uint32(pl.MAX_COPIES)]
        self_id = arena[rb + pl.SELF_WORD]
        wrong = checked & (owner != self_id)
        status = jnp.where(wrong, jnp.uint32(W.ST_WRONG_EPOCH), status)
        do_write = do_write & ~wrong

        # ---- apply ----------------------------------------------------------
        do_write = do_write & valid & ~is_nop
        arena = _write_slot(cfg, layout, arena, write_idx, write_slot, do_write)
        # link tail -> new overflow slot
        tail_slot = _read_slot(cfg, layout, arena, f["tail_idx"])
        linked = tail_slot.at[sl.NEXT_PTR].set(write_idx)
        arena = _write_slot(cfg, layout, arena, f["tail_idx"], linked,
                            link_tail & do_write)
        new_alloc = jnp.where(bump_alloc & do_write, alloc + 1, alloc)
        arena = arena.at[alloc_off].set(new_alloc)

        # ---- PL_INSTALL (update the published routing region) ---------------
        # record: [op, part, epoch, 0, copies row (MAX_COPIES) ++ alive bits]
        is_pli = op == W.OP_PL_INSTALL
        pli_go = is_pli & valid
        row_off = (jnp.uint32(rb + pl.COPIES_WORD)
                   + jnp.minimum(key_lo, jnp.uint32(cfg.n_nodes - 1))
                   * jnp.uint32(pl.MAX_COPIES)).astype(jnp.int32)
        cur_row = lax.dynamic_slice(arena, (row_off,), (pl.MAX_COPIES,))
        arena = lax.dynamic_update_slice(
            arena, jnp.where(pli_go, val[:pl.MAX_COPIES], cur_row), (row_off,))
        cur_al = lax.dynamic_slice(arena, (alive_off,), (aw,))
        arena = lax.dynamic_update_slice(
            arena, jnp.where(pli_go, val[pl.MAX_COPIES:pl.MAX_COPIES + aw],
                             cur_al), (alive_off,))
        arena = arena.at[rb + pl.EPOCH_WORD].set(
            jnp.where(pli_go, key_hi, arena[rb + pl.EPOCH_WORD]))
        status = jnp.where(is_pli, jnp.uint32(W.ST_OK), status)

        status = jnp.where(is_nop | ~valid, jnp.uint32(W.ST_BAD_OP), status)
        reply = jnp.concatenate(
            [jnp.stack([status, out_aux, out_ver]), out_val]).astype(jnp.uint32)
        return {"arena": arena}, reply

    return R.Handler(fn=fn, reply_words=cfg.reply_words, serial=True)


def make_lookup_handler_vector(cfg: HashTableConfig, layout: rg.RegionTable) -> R.Handler:
    """Read-only vectorized LOOKUP handler (used by lookup-dominated
    workloads where the inbox is known to be non-mutating)."""

    def fn(state, recs, mask):
        arena = state["arena"]
        S, C, Wrec = recs.shape
        flat = recs.reshape(S * C, Wrec)

        def one(rec):
            key_lo, key_hi = rec[1], rec[2]
            f = find(cfg, layout, arena, key_lo, key_hi)
            ok = f["found"] & (sl.slot_version(f["slot"]) % 2 == 0)
            status = jnp.where(rec[0] == W.OP_LOOKUP,
                               jnp.where(ok, W.ST_OK, W.ST_NOT_FOUND),
                               W.ST_BAD_OP).astype(jnp.uint32)
            return jnp.concatenate([
                jnp.stack([status, f["slot_idx"], sl.slot_version(f["slot"])]),
                jnp.where(ok, sl.slot_value(f["slot"]),
                          jnp.zeros((sl.VALUE_WORDS,), jnp.uint32))]).astype(jnp.uint32)

        rep = jax.vmap(one)(flat).reshape(S, C, cfg.reply_words)
        return rep

    return R.Handler(fn=fn, reply_words=cfg.reply_words, serial=False)


def make_record(op, key_lo, key_hi, aux=None, value=None):
    """Assemble (..., record_words) request records."""
    key_lo = jnp.asarray(key_lo, jnp.uint32)
    shp = key_lo.shape
    aux = jnp.zeros(shp, jnp.uint32) if aux is None else jnp.asarray(aux, jnp.uint32)
    if value is None:
        value = jnp.zeros(shp + (sl.VALUE_WORDS,), jnp.uint32)
    op = jnp.broadcast_to(jnp.asarray(op, jnp.uint32), shp)
    head = jnp.stack([op, key_lo, jnp.asarray(key_hi, jnp.uint32), aux], axis=-1)
    return jnp.concatenate([head, jnp.asarray(value, jnp.uint32)], axis=-1)
