"""Ordered remote index: a fixed-fanout B-link tree over the slot arena.

Storm's dataplane (Table 3) is data-structure-generic: a structure registers
``lookup_start`` / ``lookup_end`` client-side and an ``rpc_handler``
owner-side, and the one-two-sided hybrid plus the OCC protocol do the rest.
The hash table exercises the pointer-chase regime; this module adds the
ORDERED regime — "RDMA vs. RPC for Implementing Distributed Data Structures"
(Brock et al.) shows it is where the one-sided-vs-RPC trade-off gets
interesting: traversals favor client-side caching + one-sided reads, while
structural modifications (splits) favor RPC.  Both paths are provided:

  * **Layout**: the key space [0, 2^32-2] is RANGE-PARTITIONED evenly across
    nodes (static boundaries — the "root" of the global tree never changes).
    Each node owns a flat arena of ``n_leaves`` LEAVES; a leaf is one HEADER
    slot followed by ``leaf_width`` record slots (``slots.py`` word layout
    throughout, ``regions.py`` bounds checks apply).  The header reuses the
    slot words at leaf granularity:

        KEY_LO   = low fence key (immutable once the leaf is allocated)
        KEY_HI   = high fence key (inclusive; shrinks when the leaf splits)
        VERSION  = leaf seqlock (even = stable; EVERY record or structural
                   change bumps it — what range scans OCC-validate against)
        LOCK     = leaf lock (tx write sets lock whole leaves)
        NEXT_PTR = right-link: arena index of the key-successor leaf (the
                   B-link pointer; NULL_PTR at the partition's end)
        value[0] = live record count (records [0, count) sorted by key)

  * **Inner nodes**: a per-node separator directory (``sep`` region: fence_lo
    of every allocated leaf) — the flattened inner levels of the tree.
    Clients CACHE the directory (``refresh_meta`` = one one-sided read per
    node) and walk it locally; a probe then needs exactly ONE one-sided read
    of the predicted leaf.  Splits leave fence_lo immutable and only ADD
    separators, so a stale cache mis-predicts at most by missing new leaves —
    the probe detects it from the fetched fences and falls back to RPC
    (``OP_BT_LOOKUP`` / ``OP_BT_SCAN``), the round-trip analogue of chasing
    the B-link right-pointer.

  * **Structural ops are RPC**: ``OP_BT_INSERT``/``OP_BT_DELETE`` run in the
    serial handler; a full leaf splits (left keeps the lower half, the new
    right leaf is linked via NEXT_PTR and registered in ``sep``).  Deletes
    never merge (allocated leaves persist with their fences — the standard
    B-link simplification).

  * **Transactions at leaf granularity**: ``OP_BT_LOCK`` locks the leaf that
    covers a write key — pre-splitting a full leaf on the way down, so the
    later ``OP_BT_COMMIT`` always has room and an acquired lock can always be
    released by install+unlock.  Range scans read leaves one-sided, keep
    (node, header slot, version) as their read set, and validate leaf
    versions exactly like point transactions validate record slots (see
    ``tx.run_scan_transactions``).

Replication: every node carries a SECOND, full-range leaf arena (the
``bleaves``/``bsep``/``bnleaf`` regions) for the partitions it backs up —
ring placement puts every replicated key OUTSIDE the backup node's own
partition, and installing foreign separators into the primary tree would
corrupt its fence chain.  The handlers select the tree by key-vs-partition
(``pbounds``), so ``OP_BT_BACKUP`` installs and backup-side lookups are
served from the backup tree while primary invariants never see replica
traffic.

Limitations (documented, asserted nowhere silently): keys are the 32-bit
``key_lo`` (``key_hi`` must be 0; the hash table keeps the full 64-bit
space); one write key per leaf per transaction lane (a lane's second lock on
the same leaf reports ``ST_LOCK_FAIL``); backups replicate LOGICALLY (the
committed key/value upserted into the backup tree — leaf arenas may pack
records differently per serialization order, unlike the hash table's
byte-equal images).

Public API: ``BTreeConfig`` / ``build_layout`` / ``init_cluster_state``,
the Table-3 client half (``lookup_start`` / ``probe_end`` / ``lookup_records``
/ ``uses_probe_cache`` / ``probe_words`` / ``cache_update`` — the generic
interface ``hybrid.onesided_probe`` consumes via ``ds=``), the owner half
(``make_rpc_handler`` / ``make_lookup_handler_vector`` /
``make_scan_handler_vector``), the cached-inner-node helpers (``refresh_meta``
/ ``local_meta``), and the scan-planning helpers ``scan_plan`` /
``parse_leaf`` / ``leaf_offset`` consumed by ``tx.run_scan_transactions``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import onesided as osd
from repro.core import placement as pl
from repro.core import regions as rg
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import wireproto as W
from repro.core.datastructs.hashtable import make_record  # noqa: F401
# make_record is re-exported: the btree speaks the SAME record layout
# [op, key_lo, key_hi, aux, value...] as every other structure.

MAX_KEY = jnp.uint32(0xFFFFFFFE)   # 0xFFFFFFFF is the empty-slot sentinel


@dataclasses.dataclass(frozen=True)
class BTreeConfig:
    n_nodes: int
    n_leaves: int                # per node — static leaf arena capacity
    leaf_width: int = 4          # records per leaf (fanout)
    max_scan_leaves: int = 4     # static per-lane bound on leaves per scan

    def __post_init__(self):
        if self.leaf_width < 2:
            raise ValueError("leaf_width must be >= 2 (splits need a real "
                             f"separator key), got {self.leaf_width}")
        if self.n_leaves < 1 or self.max_scan_leaves < 1:
            raise ValueError("n_leaves and max_scan_leaves must be >= 1")

    @property
    def leaf_slots(self) -> int:        # header + records
        return 1 + self.leaf_width

    @property
    def leaf_words(self) -> int:
        return self.leaf_slots * sl.SLOT_WORDS

    # record: [op, key_lo, key_hi, aux, value...] (shared layout)
    @property
    def record_words(self) -> int:
        return 4 + sl.VALUE_WORDS

    # reply: [status, aux (header slot idx), version, value...]
    @property
    def reply_words(self) -> int:
        return 3 + sl.VALUE_WORDS

    # scan reply: [status, header slot idx] + raw leaf image
    @property
    def scan_reply_words(self) -> int:
        return 2 + self.leaf_words


def build_layout(cfg: BTreeConfig) -> rg.RegionTable:
    tbl = rg.RegionTable()
    tbl.register("leaves", cfg.n_leaves * cfg.leaf_words)
    tbl.register("sep", cfg.n_leaves)   # fence_lo per allocated leaf
    tbl.register("nleaf", 1)            # leaf bump allocator (adjacent to sep
                                        # so ONE one-sided read refreshes both)
    # The BACKUP tree: a second, independent leaf arena whose root covers the
    # FULL key space.  Ring placement makes every replicated key land OUTSIDE
    # the backup node's own partition, so installing backups into the primary
    # tree would plant foreign separators and corrupt its fence chain — the
    # handler instead routes any out-of-partition key into these regions
    # (primary invariants never see replica traffic).
    tbl.register("bleaves", cfg.n_leaves * cfg.leaf_words)
    tbl.register("bsep", cfg.n_leaves)
    tbl.register("bnleaf", 1)
    tbl.register("pbounds", 2)          # this node's inclusive partition [lo, hi]
    # coordinator-published placement table (core/placement.py) — same layout
    # and role as the hash table's: owner check + one-read client refresh
    tbl.register("routing", pl.routing_words(cfg.n_nodes))
    tbl.register("scratch", 1)          # must stay LAST (write sink)
    return tbl


# ---------------------------------------------------------------------------
# Range partition: the static "root" of the global tree
# ---------------------------------------------------------------------------
def _part(cfg: BTreeConfig) -> int:
    return (1 << 32) // cfg.n_nodes


def home_of(cfg: BTreeConfig, key):
    """Home node of a key — static range partition (clip the tail node)."""
    key = jnp.asarray(key, jnp.uint32)
    if cfg.n_nodes == 1:
        return jnp.zeros(key.shape, jnp.int32)
    node = key // jnp.uint32(_part(cfg))
    return jnp.minimum(node, jnp.uint32(cfg.n_nodes - 1)).astype(jnp.int32)


def part_of(cfg: BTreeConfig, key_lo, key_hi=None):
    """The key's PARTITION (generic placement interface): the static range
    partition — placement maps it to whatever node currently owns it."""
    return home_of(cfg, key_lo)


def partition_bounds(cfg: BTreeConfig, node):
    """(lo, hi) INCLUSIVE key bounds of a node's partition."""
    node = jnp.asarray(node, jnp.int32)
    if cfg.n_nodes == 1:
        return (jnp.zeros(node.shape, jnp.uint32),
                jnp.broadcast_to(MAX_KEY, node.shape))
    part = jnp.uint32(_part(cfg))
    lo = node.astype(jnp.uint32) * part
    hi = jnp.where(node == cfg.n_nodes - 1, MAX_KEY,
                   (node.astype(jnp.uint32) + 1) * part - 1)
    return lo, hi


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------
def init_node_state(cfg: BTreeConfig, layout: rg.RegionTable, node_id):
    """One node's arena: every slot formatted empty; the primary tree's leaf
    0 covers the node's partition, the backup tree's leaf 0 the FULL key
    space (a backup node stores OTHER partitions' keys)."""
    arena = rg.make_arena(layout)
    empty = jnp.tile(sl.make_empty_slot(), (cfg.n_leaves * cfg.leaf_slots,))
    lo, hi = partition_bounds(cfg, node_id)
    zero = jnp.uint32(0)
    for leaves, sep, nleaf, flo, fhi in (
            (layout["leaves"], layout["sep"], layout["nleaf"], lo, hi),
            (layout["bleaves"], layout["bsep"], layout["bnleaf"],
             zero, MAX_KEY)):
        arena = lax.dynamic_update_slice(arena, empty, (leaves.base,))
        hdr = sl.pack_slot(flo, fhi, 0, 0, sl.NULL_PTR,
                           jnp.zeros((sl.VALUE_WORDS,), jnp.uint32))
        arena = lax.dynamic_update_slice(arena, hdr, (leaves.base,))
        arena = arena.at[sep.base].set(flo)
        arena = arena.at[nleaf.base].set(jnp.uint32(1))
    pb = layout["pbounds"].base
    arena = arena.at[pb].set(lo).at[pb + 1].set(hi)
    rb = layout["routing"].base
    arena = lax.dynamic_update_slice(
        arena, pl.identity_region_image(cfg.n_nodes), (rb,))
    arena = arena.at[rb + pl.SELF_WORD].set(
        jnp.asarray(node_id, jnp.uint32))
    return {"arena": arena}


def init_cluster_state(cfg: BTreeConfig):
    layout = build_layout(cfg)
    return jax.vmap(lambda n: init_node_state(cfg, layout, n))(
        jnp.arange(cfg.n_nodes, dtype=jnp.int32))


def leaf_offset(cfg: BTreeConfig, layout: rg.RegionTable, leaf):
    """Arena word offset of leaf `leaf` (header slot first)."""
    return (jnp.uint32(layout["leaves"].base)
            + jnp.asarray(leaf, jnp.uint32) * jnp.uint32(cfg.leaf_words))


def header_slot(cfg: BTreeConfig, leaf):
    """Slot index (within the `leaves` region) of a leaf's header — the
    address unit the validation re-read and COMMIT addressing use."""
    return jnp.asarray(leaf, jnp.uint32) * jnp.uint32(cfg.leaf_slots)


# ---------------------------------------------------------------------------
# Cached inner nodes (the client's copy of every node's separator directory)
# ---------------------------------------------------------------------------
def local_meta(cfg: BTreeConfig, layout: rg.RegionTable, state, n_clients=None):
    """Snapshot every node's separator directory WITHOUT wire traffic (setup /
    test helper — SimTransport only).  Returns meta replicated per client:
    {"sep": (C, n_nodes, n_leaves) uint32, "nleaf": (C, n_nodes) uint32}."""
    n_clients = cfg.n_nodes if n_clients is None else n_clients
    s = layout["sep"]
    sep = state["arena"][:, s.base:s.base + cfg.n_leaves]
    nleaf = state["arena"][:, layout["nleaf"].base]
    tile = lambda x: jnp.tile(x[None], (n_clients,) + (1,) * x.ndim)
    return {"sep": tile(sep), "nleaf": tile(nleaf)}


def refresh_meta(t, state, cfg: BTreeConfig, layout: rg.RegionTable, *,
                 nic=None):
    """Refresh the cached inner nodes with ONE one-sided read per node: the
    ``sep`` and ``nleaf`` regions are adjacent, so n_leaves+1 words fetch the
    whole directory.  Returns (meta, WireStats)."""
    n_local = t.n_local
    dest = jnp.tile(jnp.arange(cfg.n_nodes, dtype=jnp.int32)[None],
                    (n_local, 1))
    off = jnp.full((n_local, cfg.n_nodes), layout["sep"].base, jnp.uint32)
    buf, _, stats = osd.remote_read(t, state["arena"], dest, off,
                                    length=cfg.n_leaves + 1, nic=nic)
    return {"sep": buf[..., :cfg.n_leaves],
            "nleaf": buf[..., cfg.n_leaves]}, stats


def refresh_backup_meta(t, state, cfg: BTreeConfig, layout: rg.RegionTable, *,
                        nic=None):
    """The BACKUP trees' separator directories (``bsep``/``bnleaf`` are
    adjacent like the primary pair, so it is again ONE one-sided read per
    node).  A scan that must be served by a backup tree — its partition's
    primary died — plans against this directory; see
    tests/test_replication.py's btree failover scans."""
    n_local = t.n_local
    dest = jnp.tile(jnp.arange(cfg.n_nodes, dtype=jnp.int32)[None],
                    (n_local, 1))
    off = jnp.full((n_local, cfg.n_nodes), layout["bsep"].base, jnp.uint32)
    buf, _, stats = osd.remote_read(t, state["arena"], dest, off,
                                    length=cfg.n_leaves + 1, nic=nic)
    return {"sep": buf[..., :cfg.n_leaves],
            "nleaf": buf[..., cfg.n_leaves]}, stats


def backup_leaf_offset(cfg: BTreeConfig, layout: rg.RegionTable, leaf):
    """Arena word offset of BACKUP-tree leaf `leaf`."""
    return (jnp.uint32(layout["bleaves"].base)
            + jnp.asarray(leaf, jnp.uint32) * jnp.uint32(cfg.leaf_words))


def _route_leaf(cfg: BTreeConfig, fences, nleaf, key):
    """fences: (..., n_leaves) fence_lo per arena leaf; nleaf: (...,).
    Returns (leaf, fence): the allocated leaf with the largest fence_lo <= key
    (leaf 0's fence is the partition low bound, so one always exists)."""
    valid = (jnp.arange(cfg.n_leaves, dtype=jnp.uint32)
             < jnp.asarray(nleaf, jnp.uint32)[..., None])
    cand = valid & (fences <= jnp.asarray(key, jnp.uint32)[..., None])
    score = jnp.where(cand, fences, 0)
    leaf = jnp.argmax(score, axis=-1).astype(jnp.uint32)
    return leaf, jnp.take_along_axis(score, leaf[..., None].astype(jnp.int32),
                                     axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Client side: the Storm Table-3 interface (consumed by hybrid via ds=btree)
# ---------------------------------------------------------------------------
def uses_probe_cache(cfg: BTreeConfig) -> bool:
    """The separator cache is per-client state (hybrid vmaps lookup_start
    over it), and lookups never update it in place (refresh is explicit)."""
    return True


def probe_words(cfg: BTreeConfig) -> int:
    """One probe reads ONE whole leaf (header + records)."""
    return cfg.leaf_words


def lookup_start(cfg: BTreeConfig, layout: rg.RegionTable, key_lo, key_hi,
                 cache=None, ptable=None):
    """Client-side metadata walk: range-partition to the node, walk the
    CACHED separator directory to the leaf.  Without a cache the probe
    targets leaf 0 and the RPC fallback resolves (correct, never fast).

    ``ptable``: optional placement.PlacementTable — route to the first LIVE
    copy instead of the static home (identity table ≡ home_of, bit-identical).
    A failed-over probe reads the backup's PRIMARY region and misses its
    fences, so the RPC fallback (which tree-selects owner-side) resolves —
    correct, never fast, exactly the no-cache degradation mode."""
    node = home_of(cfg, key_lo)
    if ptable is not None:
        node, _ = pl.live_dest(ptable, node)
    if cache is None:
        leaf = jnp.zeros(jnp.shape(key_lo), jnp.uint32)
        hit = jnp.zeros(jnp.shape(key_lo), bool)
    else:
        sep = cache["sep"][node]
        nleaf = cache["nleaf"][node]
        leaf, _ = _route_leaf(cfg, sep, nleaf, key_lo)
        hit = jnp.ones(jnp.shape(key_lo), bool)
    return node, leaf_offset(cfg, layout, leaf), hit


def parse_leaf(cfg: BTreeConfig, buf):
    """Decode one-sided leaf images.  buf: (..., leaf_words) ->
    dict(fence_lo, fence_hi, version, lock, next, count (...,),
         live/keys (..., leaf_width), values (..., leaf_width, VALUE_WORDS))."""
    shp = buf.shape[:-1]
    slots_ = buf.reshape(shp + (cfg.leaf_slots, sl.SLOT_WORDS))
    hdr, recs = slots_[..., 0, :], slots_[..., 1:, :]
    count = hdr[..., sl.VALUE0]
    live = (jnp.arange(cfg.leaf_width, dtype=jnp.uint32)
            < count[..., None])
    return dict(
        fence_lo=sl.slot_key_lo(hdr), fence_hi=sl.slot_key_hi(hdr),
        version=sl.slot_version(hdr), lock=sl.slot_lock(hdr),
        next=sl.slot_next(hdr), count=count, live=live,
        keys=sl.slot_key_lo(recs), values=sl.slot_value(recs))


def probe_end(cfg: BTreeConfig, layout: rg.RegionTable, buf, key_lo, key_hi,
              off, hit):
    """Validate a one-sided leaf read (the ordered lookup_end).

    ``resolved`` = the read CONCLUSIVELY answered the probe: stable header
    (even version, unlocked) whose fences cover the key — then a key absent
    from the records is a definitive miss (no chains to chase), unlike the
    hash table where found and resolved coincide.  A fence miss means the
    cached separators are stale (the leaf split since) — the RPC fallback
    re-walks at the owner."""
    p = parse_leaf(cfg, buf)
    key = jnp.asarray(key_lo, jnp.uint32)
    stable = (p["version"] % 2 == 0) & (p["lock"] == 0)
    in_fence = (p["fence_lo"] <= key) & (key <= p["fence_hi"])
    resolved = stable & in_fence & (jnp.asarray(key_hi, jnp.uint32) == 0)
    m = p["live"] & (p["keys"] == key[..., None])
    found = resolved & jnp.any(m, axis=-1)
    idx = jnp.argmax(m, axis=-1)
    value = jnp.take_along_axis(p["values"], idx[..., None, None], axis=-2)[..., 0, :]
    value = jnp.where(found[..., None], value, jnp.zeros_like(value))
    leaf = ((jnp.asarray(off, jnp.uint32) - jnp.uint32(layout["leaves"].base))
            // jnp.uint32(cfg.leaf_words))
    return dict(found=found, value=value, version=p["version"],
                slot_idx=header_slot(cfg, leaf), resolved=resolved)


def lookup_records(cfg: BTreeConfig, key_lo, key_hi):
    """Request records for the point-lookup RPC fallback."""
    return make_record(W.OP_BT_LOOKUP, key_lo, key_hi)


def cache_update(cfg: BTreeConfig, cache, key_lo, key_hi, node, slot_idx,
                 valid):
    """Per-lookup cache learning is a no-op: the separator cache is refreshed
    wholesale by ``refresh_meta`` (a probe teaches nothing the directory it
    routed with did not already contain)."""
    return cache


# ---------------------------------------------------------------------------
# Scan planning: which (node, leaf) sequence covers [lo, hi]?
# ---------------------------------------------------------------------------
def scan_plan(cfg: BTreeConfig, meta_sep, meta_nleaf, lo, hi):
    """Plan one client node's scans from its cached separators.

    meta_sep: (n_nodes, n_leaves); meta_nleaf: (n_nodes,); lo/hi: (B,) uint32
    INCLUSIVE ranges (lo > hi = lane scans nothing).  Returns dict of
    (B, max_scan_leaves) arrays: node, leaf, fence (the expected fence_lo —
    immutable per leaf, so it double-checks routing AND addresses the RPC
    fallback), enabled.

    The global leaf order is (node, fence_lo) — partitions are static and
    tile the key space, so sorting the flattened directory once per client
    yields every lane's leaf run by rank arithmetic."""
    n, L = meta_sep.shape
    S = cfg.max_scan_leaves
    gnode = jnp.repeat(jnp.arange(n, dtype=jnp.int32), L)
    gleaf = jnp.tile(jnp.arange(L, dtype=jnp.uint32), n)
    gfence = meta_sep.reshape(-1)
    gvalid = (jnp.arange(L, dtype=jnp.uint32)[None, :]
              < jnp.asarray(meta_nleaf, jnp.uint32)[:, None]).reshape(-1)
    order = jnp.lexsort((gfence, jnp.where(gvalid, gnode, n)))
    snode, sleaf, sfence = gnode[order], gleaf[order], gfence[order]
    total = jnp.sum(gvalid.astype(jnp.int32))

    lo = jnp.asarray(lo, jnp.uint32)
    hi = jnp.asarray(hi, jnp.uint32)
    node0 = home_of(cfg, lo)                               # (B,)
    _, f0 = _route_leaf(cfg, meta_sep[node0], meta_nleaf[node0], lo)
    before = gvalid[None, :] & (
        (gnode[None, :] < node0[:, None])
        | ((gnode[None, :] == node0[:, None]) & (gfence[None, :] < f0[:, None])))
    rank0 = jnp.sum(before.astype(jnp.int32), axis=-1)     # (B,)
    k = rank0[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    kc = jnp.minimum(k, n * L - 1)
    en = (k < total) & (sfence[kc] <= hi[:, None]) & (lo <= hi)[:, None]
    return dict(node=snode[kc], leaf=sleaf[kc], fence=sfence[kc], enabled=en)


def scan_records(cfg: BTreeConfig, plan):
    """OP_BT_SCAN request records for the per-position RPC fallback: the
    expected fence_lo addresses the leaf (fence_lo is immutable, so the owner
    walk lands on exactly the planned leaf, with authoritative fences)."""
    return make_record(W.OP_BT_SCAN, plan["fence"], jnp.zeros_like(plan["fence"]))


# ---------------------------------------------------------------------------
# Owner side: serial handler (mutations, locks, commits) + vector handlers
# ---------------------------------------------------------------------------
def _read_leaf(cfg, layout, arena, leaf, base=None):
    """base: word offset of the leaf arena to address (default the primary
    `leaves` region; the handler passes a traced base to select the backup
    tree for out-of-partition keys)."""
    if base is None:
        base = jnp.uint32(layout["leaves"].base)
    off = (jnp.asarray(base, jnp.uint32)
           + jnp.asarray(leaf, jnp.uint32) * jnp.uint32(cfg.leaf_words))
    flat = lax.dynamic_slice(arena, (off.astype(jnp.int32),), (cfg.leaf_words,))
    return flat.reshape(cfg.leaf_slots, sl.SLOT_WORDS)


def _write_leaf(cfg, layout, arena, leaf, image, enabled, base=None):
    if base is None:
        base = jnp.uint32(layout["leaves"].base)
    off = (jnp.asarray(base, jnp.uint32)
           + jnp.asarray(leaf, jnp.uint32) * jnp.uint32(cfg.leaf_words))
    off = off.astype(jnp.int32)
    cur = lax.dynamic_slice(arena, (off,), (cfg.leaf_words,))
    new = jnp.where(enabled, image.reshape(-1), cur)
    return lax.dynamic_update_slice(arena, new, (off,))


# Handler constructors are memoized per config: handlers are pure closures
# over (cfg, layout), layout is a deterministic function of cfg
# (build_layout), and a STABLE fn identity is what lets jax reuse the
# compiled serial fold / vectorized walk across calls (a fresh closure per
# call would recompile the owner-side scan every exchange round).
_handler_cache: dict = {}


def _cached(kind, cfg, build):
    h = _handler_cache.get((kind, cfg))
    if h is None:
        h = _handler_cache[(kind, cfg)] = build()
    return h


def make_rpc_handler(cfg: BTreeConfig, layout: rg.RegionTable) -> R.Handler:
    """The serial (mutating) rpc_handler — one registered handler serves
    every btree opcode, like the hash table's.

    Record layout [op, key_lo, key_hi, aux, value...]:
      * LOOKUP/INSERT/DELETE: key in key_lo (key_hi must be 0), aux unused.
      * LOCK: aux = caller's lock tag.  A full leaf that must later absorb an
        insert is PRE-SPLIT here (split on the way down), so COMMIT never
        lacks space — the lock-is-always-released invariant of the hash
        table's commit carries over.
      * COMMIT/ABORT: key_hi carries the lock tag, aux the header slot index
        from the LOCK reply (direct addressing, no walk).
      * BACKUP: logical replica install — an upsert on THIS node's tree.
    Reply: [status, header slot idx of the key's leaf, leaf version, value].
    """
    return _cached("serial", cfg, lambda: _make_rpc_handler(cfg, layout))


def _make_rpc_handler(cfg: BTreeConfig, layout: rg.RegionTable) -> R.Handler:
    lw, lslots = cfg.leaf_width, cfg.leaf_slots
    left_n = (lw + 1) // 2
    empty = sl.make_empty_slot()
    pb = layout["pbounds"].base

    def fn(state, rec, valid):
        arena = state["arena"]
        op, key, key_hi, aux = rec[0], rec[1], rec[2], rec[3]
        val = rec[4:4 + sl.VALUE_WORDS]
        # tree selection: keys inside this node's partition live in the
        # PRIMARY tree; out-of-partition keys are replica traffic and live in
        # the full-range BACKUP tree (foreign separators must never enter the
        # primary fence chain).  All leaf/sep/alloc accesses below use the
        # selected bases.
        foreign = (key < arena[pb]) | (key > arena[pb + 1])
        pick = lambda p, b: jnp.where(foreign, jnp.uint32(layout[b].base),
                                      jnp.uint32(layout[p].base))
        leaves_base = pick("leaves", "bleaves")
        sep_base = pick("sep", "bsep").astype(jnp.int32)
        nleaf_off = pick("nleaf", "bnleaf").astype(jnp.int32)
        nleaf = arena[nleaf_off]
        sep = lax.dynamic_slice(arena, (sep_base,), (cfg.n_leaves,))
        routed, _ = _route_leaf(cfg, sep, nleaf, key)

        is_lookup = op == W.OP_BT_LOOKUP
        is_ins = op == W.OP_BT_INSERT
        is_del = op == W.OP_BT_DELETE
        is_lock = op == W.OP_BT_LOCK
        is_commit = op == W.OP_BT_COMMIT
        is_abort = op == W.OP_BT_ABORT
        is_bkw = op == W.OP_BT_BACKUP
        known = (is_lookup | is_ins | is_del | is_lock | is_commit | is_abort
                 | is_bkw)

        # ---- placement epoch check (lock-class ops only) -----------------
        # A request routed by a STALE table lands on a node that no longer
        # owns the key's partition: reject with ST_WRONG_EPOCH before any
        # write, so rebalance is invisible to in-flight transactions (they
        # abort `stale_route`, refresh, retry).  COMMIT/ABORT stay unchecked
        # — locks taken under the old epoch must remain releasable — and
        # backups/lookups are replica traffic by design.
        rb = layout["routing"].base
        checked = is_ins | is_del | is_lock
        part_ = home_of(cfg, key).astype(jnp.uint32)
        owner = arena[(jnp.uint32(rb + pl.COPIES_WORD)
                       + part_ * jnp.uint32(pl.MAX_COPIES)).astype(jnp.int32)]
        self_id = arena[rb + pl.SELF_WORD]
        wrong = checked & (owner != self_id)

        # COMMIT/ABORT address their leaf directly (header slot from LOCK)
        direct = is_commit | is_abort
        leaf = jnp.where(direct, aux // jnp.uint32(lslots), routed)
        L = _read_leaf(cfg, layout, arena, leaf, base=leaves_base)
        hdr, recs = L[0], L[1:]
        ver, lock = sl.slot_version(hdr), sl.slot_lock(hdr)
        count = hdr[sl.VALUE0]
        live = jnp.arange(lw, dtype=jnp.uint32) < count
        m = live & (recs[:, sl.KEY_LO] == key)
        present = jnp.any(m)
        pidx = jnp.argmax(m)
        cur_val = recs[pidx, sl.VALUE0:]
        locked = lock != 0
        full = count >= jnp.uint32(lw)
        can_alloc = nleaf < jnp.uint32(cfg.n_leaves)
        own = locked & (lock == key_hi)     # COMMIT/ABORT tag check

        # ---- decide the mutation shape ----------------------------------
        mut_ok = ~locked            # plain mutations need an unlocked leaf
        upd = present & ((is_ins | is_bkw) & mut_ok | (is_commit & own))
        dele = is_del & present & mut_ok
        space_ok = ~full | can_alloc
        want_ins = ~present & ((is_ins | is_bkw) & mut_ok & space_ok
                               | (is_commit & own & space_ok))
        presplit = is_lock & mut_ok & ~present & full & can_alloc
        do_split = (want_ins & full) | presplit
        lock_ok = is_lock & mut_ok & (present | space_ok)

        # ---- sorted rebuild: clean records, apply update/delete, append
        # the (possibly empty) new record, sort by key (empties sort last,
        # and the live prefix is already sorted, so non-mutating ops are
        # identity) --------------------------------------------------------
        new_rec = sl.pack_slot(key, 0, 0, 0, sl.NULL_PTR, val)
        base = jnp.where(live[:, None], recs, empty[None, :])
        base = jnp.where((m & upd)[:, None], new_rec[None, :], base)
        base = jnp.where((m & dele)[:, None], empty[None, :], base)
        ext = jnp.concatenate(
            [base, jnp.where(want_ins, new_rec, empty)[None, :]], axis=0)
        order = jnp.argsort(ext[:, sl.KEY_LO], stable=True)
        sorted_ext = ext[order]                       # (lw+1, SLOT_WORDS)
        total = count + want_ins.astype(jnp.uint32) - dele.astype(jnp.uint32)

        split_key = sorted_ext[left_n, sl.KEY_LO]
        right_idx = nleaf
        right_n = total - jnp.uint32(left_n)
        key_right = do_split & (key >= split_key)     # key lands in new leaf

        # ---- left (routed) leaf image ------------------------------------
        keep = jnp.arange(lw, dtype=jnp.uint32) < jnp.where(
            do_split, jnp.uint32(left_n), total)
        left_recs = jnp.where(keep[:, None], sorted_ext[:lw], empty[None, :])
        bump = upd | dele | want_ins | do_split
        new_ver = jnp.where(bump, ver + 2, ver)
        new_lock = lock
        new_lock = jnp.where(lock_ok & ~key_right, aux, new_lock)
        new_lock = jnp.where((is_commit | is_abort) & own, 0, new_lock)
        left_hdr = sl.pack_slot(
            sl.slot_key_lo(hdr),
            jnp.where(do_split, split_key - 1, sl.slot_key_hi(hdr)),
            new_ver, new_lock,
            jnp.where(do_split, right_idx, sl.slot_next(hdr)),
            hdr[sl.VALUE0:].at[0].set(jnp.where(do_split, jnp.uint32(left_n),
                                                total)))
        left_img = jnp.concatenate([left_hdr[None, :], left_recs], axis=0)
        wrote = bump | lock_ok | ((is_commit | is_abort) & own)

        # ---- right (new) leaf image on split -----------------------------
        ridx = jnp.minimum(jnp.arange(lw) + left_n, lw)
        rkeep = (jnp.arange(lw, dtype=jnp.uint32) < right_n)[:, None]
        right_recs = jnp.where(rkeep, sorted_ext[ridx], empty[None, :])
        right_hdr = sl.pack_slot(
            split_key, sl.slot_key_hi(hdr), ver + 2,
            jnp.where(lock_ok & key_right, aux, 0),
            sl.slot_next(hdr),
            jnp.zeros((sl.VALUE_WORDS,), jnp.uint32).at[0].set(right_n))
        right_img = jnp.concatenate([right_hdr[None, :], right_recs], axis=0)

        # ---- statuses ----------------------------------------------------
        ok32 = jnp.uint32(W.ST_OK)
        status = jnp.uint32(W.ST_BAD_OP)
        status = jnp.where(is_lookup, jnp.where(
            present, W.ST_OK, W.ST_NOT_FOUND).astype(jnp.uint32), status)
        status = jnp.where(is_ins | is_bkw, jnp.where(
            locked, W.ST_LOCK_FAIL,
            jnp.where(present | space_ok, W.ST_OK,
                      W.ST_NO_SPACE)).astype(jnp.uint32), status)
        status = jnp.where(is_del, jnp.where(
            present, jnp.where(locked, W.ST_LOCK_FAIL, W.ST_OK),
            W.ST_NOT_FOUND).astype(jnp.uint32), status)
        status = jnp.where(is_lock, jnp.where(
            locked, W.ST_LOCK_FAIL,
            jnp.where(present | space_ok, W.ST_OK,
                      W.ST_NO_SPACE)).astype(jnp.uint32), status)
        status = jnp.where(direct,
                           jnp.where(own, ok32, jnp.uint32(W.ST_LOCK_FAIL)),
                           status)
        status = jnp.where(wrong, jnp.uint32(W.ST_WRONG_EPOCH), status)

        tgt_leaf = jnp.where(key_right, right_idx, leaf)
        out_aux = header_slot(cfg, tgt_leaf)
        # version of the key's leaf as the caller will see it: the lock reply
        # reports the (even) post-presplit version its commit builds on
        out_ver = jnp.where(bump, ver + 2, ver)
        out_ver = jnp.where(presplit, ver + 2, out_ver)
        out_val = jnp.where(present & (is_lookup | is_lock), cur_val,
                            jnp.zeros_like(cur_val))

        # ---- apply (all addressed through the selected tree's bases) -----
        go = valid & known & ~wrong
        arena = _write_leaf(cfg, layout, arena, leaf, left_img, wrote & go,
                            base=leaves_base)
        safe_right = jnp.minimum(right_idx, jnp.uint32(cfg.n_leaves - 1))
        arena = _write_leaf(cfg, layout, arena, safe_right, right_img,
                            do_split & go, base=leaves_base)
        sep_idx = sep_base + safe_right.astype(jnp.int32)
        arena = arena.at[sep_idx].set(
            jnp.where(do_split & go, split_key, arena[sep_idx]))
        arena = arena.at[nleaf_off].set(
            jnp.where(do_split & go, nleaf + 1, nleaf))

        # ---- OP_PL_INSTALL: update the routing region (placement-table
        # broadcast; PL is not in `known`, so no leaf write above fired).
        # Record: [op, part, epoch, 0, copies row ++ alive bits ++ 0...].
        is_pli = op == W.OP_PL_INSTALL
        pli_go = is_pli & valid
        aw = pl.alive_words(cfg.n_nodes)
        row_off = (jnp.uint32(rb + pl.COPIES_WORD)
                   + jnp.minimum(key, jnp.uint32(cfg.n_nodes - 1))
                   * jnp.uint32(pl.MAX_COPIES)).astype(jnp.int32)
        cur_row = lax.dynamic_slice(arena, (row_off,), (pl.MAX_COPIES,))
        arena = lax.dynamic_update_slice(
            arena, jnp.where(pli_go, val[:pl.MAX_COPIES], cur_row), (row_off,))
        alive_off = rb + pl.COPIES_WORD + cfg.n_nodes * pl.MAX_COPIES
        cur_al = lax.dynamic_slice(arena, (alive_off,), (aw,))
        arena = lax.dynamic_update_slice(
            arena, jnp.where(pli_go, val[pl.MAX_COPIES:pl.MAX_COPIES + aw],
                             cur_al), (alive_off,))
        arena = arena.at[rb + pl.EPOCH_WORD].set(
            jnp.where(pli_go, key_hi, arena[rb + pl.EPOCH_WORD]))
        status = jnp.where(is_pli, jnp.uint32(W.ST_OK), status)

        status = jnp.where(valid, status, jnp.uint32(W.ST_BAD_OP))
        reply = jnp.concatenate(
            [jnp.stack([status, out_aux, out_ver]), out_val]).astype(jnp.uint32)
        return {"arena": arena}, reply

    return R.Handler(fn=fn, reply_words=cfg.reply_words, serial=True)


def make_lookup_handler_vector(cfg: BTreeConfig,
                               layout: rg.RegionTable) -> R.Handler:
    """Read-only vectorized OP_BT_LOOKUP handler: the owner-side separator
    walk + leaf search (the point-probe RPC fallback)."""
    return _cached("lookup", cfg, lambda: _make_lookup_vector(cfg, layout))


def _make_lookup_vector(cfg: BTreeConfig, layout: rg.RegionTable) -> R.Handler:
    pb = layout["pbounds"].base

    def fn(state, recs, mask):
        arena = state["arena"]
        S_, C, Wrec = recs.shape
        flat = recs.reshape(S_ * C, Wrec)

        def one(rec):
            key = rec[1]
            # same tree selection as the serial handler: out-of-partition
            # keys are replica copies served from the backup tree (this is
            # what a read that failed over to a backup resolves against)
            foreign = (key < arena[pb]) | (key > arena[pb + 1])
            pick = lambda p, b: jnp.where(
                foreign, jnp.uint32(layout[b].base),
                jnp.uint32(layout[p].base))
            sep = lax.dynamic_slice(
                arena, (pick("sep", "bsep").astype(jnp.int32),),
                (cfg.n_leaves,))
            nleaf = arena[pick("nleaf", "bnleaf").astype(jnp.int32)]
            leaf, _ = _route_leaf(cfg, sep, nleaf, key)
            L = _read_leaf(cfg, layout, arena, leaf,
                           base=pick("leaves", "bleaves"))
            hdr, rr = L[0], L[1:]
            live = (jnp.arange(cfg.leaf_width, dtype=jnp.uint32)
                    < hdr[sl.VALUE0])
            m = live & (rr[:, sl.KEY_LO] == key)
            present = jnp.any(m) & (rec[2] == 0)
            value = jnp.where(present, rr[jnp.argmax(m), sl.VALUE0:], 0)
            status = jnp.where(
                rec[0] == W.OP_BT_LOOKUP,
                jnp.where(present, W.ST_OK, W.ST_NOT_FOUND),
                W.ST_BAD_OP).astype(jnp.uint32)
            head = jnp.stack([status, header_slot(cfg, leaf),
                              sl.slot_version(hdr)])
            return jnp.concatenate([head, value]).astype(jnp.uint32)

        return jax.vmap(one)(flat).reshape(S_, C, cfg.reply_words)

    return R.Handler(fn=fn, reply_words=cfg.reply_words, serial=False)


def make_scan_handler_vector(cfg: BTreeConfig,
                             layout: rg.RegionTable) -> R.Handler:
    """Read-only OP_BT_SCAN handler: return the FULL image of the leaf
    covering the record's key (the range-scan fallback — the owner re-walks
    its authoritative separators, the round-trip analogue of following a
    B-link right-pointer after a stale route).  Reply:
    [status, header slot idx] ++ raw leaf image."""
    return _cached("scan", cfg, lambda: _make_scan_vector(cfg, layout))


def _make_scan_vector(cfg: BTreeConfig, layout: rg.RegionTable) -> R.Handler:
    # scans are a PRIMARY-tree protocol: plans are built from the primary
    # separator directory and scan ranges route to their home partition, so
    # the fallback walks the primary tree only (backup copies are reached by
    # point lookups / failover, never by in-partition scans)
    sep_base = layout["sep"].base
    nleaf_off = layout["nleaf"].base

    def fn(state, recs, mask):
        arena = state["arena"]
        S_, C, Wrec = recs.shape
        flat = recs.reshape(S_ * C, Wrec)
        sep = lax.dynamic_slice(arena, (sep_base,), (cfg.n_leaves,))
        nleaf = arena[nleaf_off]

        def one(rec):
            key = rec[1]
            leaf, _ = _route_leaf(cfg, sep, nleaf, key)
            img = _read_leaf(cfg, layout, arena, leaf).reshape(-1)
            status = jnp.where(rec[0] == W.OP_BT_SCAN, W.ST_OK,
                               W.ST_BAD_OP).astype(jnp.uint32)
            return jnp.concatenate(
                [jnp.stack([status, header_slot(cfg, leaf)]), img]
            ).astype(jnp.uint32)

        return jax.vmap(one)(flat).reshape(S_, C, cfg.scan_reply_words)

    return R.Handler(fn=fn, reply_words=cfg.scan_reply_words, serial=False)
