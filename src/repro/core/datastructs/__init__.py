from repro.core.datastructs import hashtable  # noqa: F401
