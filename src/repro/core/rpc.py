"""Write-based RPC (Storm §5.2).

Storm implements RPC with ``rdma_write_with_imm``: the request is WRITTEN into
a receive ring at the callee, a completion with an immediate header pops out
of ONE shared completion queue, the handler runs, and the reply is written
back the same way.  Our realization:

  * request records are written into per-owner INBOX buffers by an all-to-all
    (= the one-sided write of the request),
  * the cell coordinates (src, slot) play the role of the immediate header
    identifying sender and coroutine lane,
  * ONE fused validity mask per inbox = the single completion queue,
  * the owner runs the registered handler over its inbox, then replies are
    written back by the mirror all-to-all.

Handlers come in two flavours:
  * ``serial``  — mutating ops.  Records are folded sequentially through the
    node state (lax.scan), which gives genuine mutual-exclusion semantics for
    locks/inserts: the order of the scan is the serialization order.
  * ``vector``  — read-only ops (lookups): vectorized across the inbox.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.transport import (Transport, WireStats, pick_replies,
                                  route_by_dest, wire_for)

# Well-known opcodes (data structures may extend >= 16)
OP_NOP = 0
OP_LOOKUP = 1
OP_INSERT = 2
OP_UPDATE = 3
OP_DELETE = 4
OP_LOCK = 5           # lock write-set entry (returns version at lock time)
OP_COMMIT_UNLOCK = 6  # install value, version += 2, unlock
OP_ABORT_UNLOCK = 7   # release lock without installing
OP_READ_VERSION = 8   # validation re-read by RPC (fallback path)

# Reply status codes (word 0 of every reply)
ST_OK = 0
ST_NOT_FOUND = 1
ST_LOCK_FAIL = 2
ST_NO_SPACE = 3   # handler-returned: storage full (request WAS delivered)
ST_BAD_OP = 4
ST_DROPPED = 5    # transport-level: request never delivered (send-queue
                  # overflow or parked lane) — retryable back-pressure,
                  # distinct from the permanent ST_NO_SPACE


@dataclasses.dataclass(frozen=True)
class Handler:
    """A registered rpc_handler (Storm Table 3)."""
    fn: Callable            # see serial/vector signatures below
    reply_words: int
    serial: bool = True


def serial_apply(handler_fn, state, records, mask, reply_words: int):
    """Fold records through node state in a fixed serialization order.

    handler_fn(state, record (W,), valid) -> (state, reply (reply_words,))
    records: (S, C, W); mask: (S, C) -> replies (S, C, reply_words)
    """
    S, C, W = records.shape
    flat_r = records.reshape(S * C, W)
    flat_m = mask.reshape(S * C)

    def step(st, rm):
        rec, valid = rm
        st, rep = handler_fn(st, rec, valid)
        return st, rep

    state, flat_rep = lax.scan(step, state, (flat_r, flat_m))
    return state, flat_rep.reshape(S, C, reply_words)


def vector_apply(handler_fn, state, records, mask, reply_words: int):
    """handler_fn(state, records (S,C,W), mask) -> replies (S,C,reply_words).
    State is read-only on this path."""
    return state, handler_fn(state, records, mask)


@partial(jax.named_call, name="storm_rpc")
def rpc_call(t: Transport, state, dest, records, handler: Handler, *,
             capacity: Optional[int] = None, enabled=None):
    """Batched write-based RPC round (one round trip for B lanes/node).

    state:   pytree with leading node axis (N_local, ...)
    dest:    (N_local, B) int32
    records: (N_local, B, W) uint32 (word 0 must be the opcode)
    enabled: optional (N_local, B) bool — lanes that actually issue the RPC.
             Disabled lanes are parked by route_by_dest (no send-queue cell,
             no capacity consumed, no wire bytes).

    Returns (state, replies (N_local, B, R), overflow (N_local, B), WireStats).
    Overflowed and parked lanes carry ST_DROPPED in reply word 0 so a lane
    that issued no request can never be mistaken for success — or for a
    handler-returned ST_NO_SPACE, which means the request WAS delivered but
    storage is full (not retryable).
    """
    B = dest.shape[-1]
    cap = capacity or B
    if enabled is not None:
        buf, mask, pos, ovf = jax.vmap(
            lambda d, p, e: route_by_dest(d, p, t.n_nodes, cap, e)
        )(dest, records, enabled)
    else:
        buf, mask, pos, ovf = jax.vmap(
            lambda d, p: route_by_dest(d, p, t.n_nodes, cap))(dest, records)
    inbox = t.exchange(buf)
    inbox_mask = t.exchange(mask)

    apply_fn = serial_apply if handler.serial else vector_apply

    def per_node(st, recs, msk):
        return apply_fn(handler.fn, st, recs, msk, handler.reply_words)

    state, replies = jax.vmap(per_node)(state, inbox, inbox_mask)
    back = t.exchange(replies)
    out = jax.vmap(pick_replies)(back, dest, pos, ovf)
    # Lanes that issued no request must not alias ST_OK: a zeroed reply's
    # word 0 reads as success, so stamp the status word with ST_DROPPED for
    # overflowed AND parked (disabled) lanes.
    no_reply = ovf if enabled is None else (ovf | ~enabled)
    out = out.at[..., 0].set(
        jnp.where(no_reply, jnp.uint32(ST_DROPPED), out[..., 0]))
    stats = wire_for(mask, req_words=records.shape[-1],
                     reply_words=handler.reply_words)
    return state, out, ovf, stats
