"""Write-based RPC (Storm §5.2).

Storm implements RPC with ``rdma_write_with_imm``: the request is WRITTEN into
a receive ring at the callee, a completion with an immediate header pops out
of ONE shared completion queue, the handler runs, and the reply is written
back the same way.  Our realization:

  * request records are written into per-owner INBOX buffers by an all-to-all
    (= the one-sided write of the request),
  * the cell coordinates (src, slot) play the role of the immediate header
    identifying sender and coroutine lane,
  * ONE fused validity mask per inbox = the single completion queue,
  * the owner runs the registered handler over its inbox, then replies are
    written back by the mirror all-to-all.

Handlers come in two flavours:
  * ``serial``  — mutating ops.  Records are folded sequentially through the
    node state (lax.scan), which gives genuine mutual-exclusion semantics for
    locks/inserts: the order of the scan is the serialization order.
  * ``vector``  — read-only ops (lookups): vectorized across the inbox.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax

from repro.core import roundsched as rs
from repro.core.roundsched import serial_apply, vector_apply  # noqa: F401  (re-export)
from repro.core.transport import Transport, WireStats  # noqa: F401  (re-export)

# Opcodes and reply statuses live in core/wireproto.py — the single
# registration point for every data structure's wire contract.  They are
# re-exported here so the historical ``R.OP_*`` / ``R.ST_*`` spelling keeps
# working everywhere.
from repro.core.wireproto import (  # noqa: F401  (re-export)
    OP_ABORT_UNLOCK, OP_BACKUP_WRITE, OP_BT_ABORT, OP_BT_BACKUP, OP_BT_COMMIT,
    OP_BT_DELETE, OP_BT_INSERT, OP_BT_LOCK, OP_BT_LOOKUP, OP_BT_SCAN,
    OP_COMMIT_UNLOCK, OP_DELETE, OP_INSERT, OP_LOCK, OP_LOOKUP, OP_NOP,
    OP_PL_INSTALL, OP_READ_VERSION, OP_UPDATE, ST_BAD_OP, ST_DROPPED,
    ST_LOCK_FAIL, ST_NOT_FOUND, ST_NO_SPACE, ST_OK, ST_WRONG_EPOCH)


@dataclasses.dataclass(frozen=True)
class Handler:
    """A registered rpc_handler (Storm Table 3)."""
    fn: Callable            # see roundsched serial/vector signatures
    reply_words: int
    serial: bool = True


@partial(jax.named_call, name="storm_rpc")
def rpc_call(t: Transport, state, dest, records, handler: Handler, *,
             capacity: Optional[int] = None, enabled=None, nic=None,
             telemetry=None, phase: int = 0):
    """Batched write-based RPC round (one round trip for B lanes/node) — a
    single-class fused round (see roundsched.fused_round).

    state:   pytree with leading node axis (N_local, ...)
    dest:    (N_local, B) int32
    records: (N_local, B, W) uint32 (word 0 must be the opcode)
    enabled: optional (N_local, B) bool — lanes that actually issue the RPC.
             Disabled lanes are parked by route_by_dest (no send-queue cell,
             no capacity consumed, no wire bytes).
    capacity: per-destination send-queue budget.  ``None`` means B (a full
             batch always fits); 0 is honoured as "deliver nothing" (every
             enabled lane back-pressured), negative values are rejected.

    Returns (state, replies (N_local, B, R), overflow (N_local, B), WireStats).
    Overflowed and parked lanes carry ST_DROPPED in reply word 0 so a lane
    that issued no request can never be mistaken for success — or for a
    handler-returned ST_NO_SPACE, which means the request WAS delivered but
    storage is full (not retryable).
    """
    state, ((out, ovf),), stats = rs.fused_round(
        t, state,
        [rs.rpc_class(dest, records, handler, enabled=enabled,
                      capacity=capacity)], nic=nic, telemetry=telemetry,
        phase=phase)
    return state, out, ovf, stats
