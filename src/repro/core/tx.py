"""Storm transactional protocol (§5.4, Fig. 3): OCC + 2PC optimized for the
dataplane's two primitives.

Per transaction lane:
  EXECUTE   read-set via one-two-sided hybrid lookups (reads buffered
            locally), write-set read-for-update + LOCK via write-based RPC
            (the paper locks intended writes during execution).
  VALIDATE  re-read read-set slot versions with ONE-SIDED reads (Storm keeps
            the remote offsets of every read-set object).
  COMMIT    write-based RPCs install values, bump versions to even, unlock.
  ABORT     unlock / roll back placeholder inserts for lanes whose locks
            failed or whose validation detected a concurrent writer.

Shapes are static: each lane has exactly R read keys and W write keys; lanes
are batched B per node ("coroutines"), so a full transaction costs the same
FIVE pipeline rounds the paper's Figure 3 shows, independent of B:
    read (1-2 RTs: read + masked RPC) + lock (1) + validate (1) + commit (1).

The protocol is factored into per-phase functions (execute_read_set /
lock_write_set / validate_read_set / commit_or_abort) so that
``run_transactions`` (single shot) and ``txloop.tx_loop`` (bounded-retry
engine) share one implementation of every phase.  Aborts are classified by
cause — lock conflict, validation conflict, or overflow/back-pressure — which
is what the retry loop and the contention benchmarks report.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hybrid as hy
from repro.core import onesided as osd
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport, WireStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxResult:
    committed: jnp.ndarray        # (N, B) bool
    read_found: jnp.ndarray       # (N, B, R) bool
    read_values: jnp.ndarray      # (N, B, R, VALUE_WORDS)
    locked_values: jnp.ndarray    # (N, B, W, VALUE_WORDS) read-for-update values
    aborted_lock: jnp.ndarray     # (N, B) bool — lost a lock race
    aborted_validate: jnp.ndarray  # (N, B) bool — read-set changed underfoot
    aborted_overflow: jnp.ndarray  # (N, B) bool — back-pressure / no space
    metrics: hy.HybridMetrics
    round_trips: jnp.ndarray      # scalar


# ---------------------------------------------------------------------------
# Phase functions.  Each takes/returns cluster state plus a plain dict of
# per-item arrays; lane axes are flattened to (N, B*K) like the wire sees them.
# ---------------------------------------------------------------------------
def execute_read_set(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
                     read_keys, read_enabled, cache=None,
                     use_onesided: bool = True, capacity: Optional[int] = None):
    """EXECUTE phase, read half: one-two-sided lookups of the read set.

    read_keys: (N, B, Rd, 2); read_enabled: (N, B, Rd) bool.
    Returns (state, cache, ctx) where ctx holds the flattened (N, B*Rd)
    found/values/versions/owner/slot arrays the later phases need.
    """
    N, B, Rd = read_keys.shape[:3]
    rk_lo = read_keys[..., 0].reshape(N, B * Rd)
    rk_hi = read_keys[..., 1].reshape(N, B * Rd)
    en = read_enabled.reshape(N, B * Rd)
    state, cache, found, rvals, rvers, rnode, rslot, rovf, m = hy.hybrid_lookup(
        t, state, rk_lo, rk_hi, cfg, layout, cache=cache,
        use_onesided=use_onesided, rpc_serial=False, capacity=capacity,
        enabled=en)
    return state, cache, dict(
        key_lo=rk_lo, key_hi=rk_hi, enabled=en, found=found, values=rvals,
        versions=rvers, node=rnode, slot=rslot, overflow=rovf, metrics=m)


def lock_write_set(t: Transport, state, cfg: ht.HashTableConfig, layout,
                   serial_h, *, write_keys, write_enabled,
                   capacity: Optional[int] = None):
    """EXECUTE phase, write half: LOCK + read-for-update the write set.

    write_keys: (N, B, Wr, 2); write_enabled: (N, B, Wr) bool.
    """
    N, B, Wr = write_keys.shape[:3]
    wk_lo = write_keys[..., 0].reshape(N, B * Wr)
    wk_hi = write_keys[..., 1].reshape(N, B * Wr)
    en = write_enabled.reshape(N, B * Wr)
    wnode, _, _ = ht.lookup_start(cfg, layout, wk_lo, wk_hi, None)
    # unique nonzero lock tag per (node, lane)
    lane = jnp.arange(B * Wr, dtype=jnp.uint32) // jnp.uint32(max(Wr, 1))
    tag = (t.node_ids().astype(jnp.uint32)[:, None] * jnp.uint32(B)
           + lane[None, :] + jnp.uint32(1))
    lock_recs = ht.make_record(R.OP_LOCK, wk_lo, wk_hi, aux=tag)
    state, lrep, lovf, s_lock = R.rpc_call(
        t, state, wnode, lock_recs, serial_h, capacity=capacity, enabled=en)
    status = lrep[..., 0]
    lock_ok = (status == R.ST_OK) & ~lovf & en
    return state, dict(
        key_lo=wk_lo, key_hi=wk_hi, enabled=en, node=wnode,
        lock_ok=lock_ok, lock_slot=lrep[..., 1],
        locked_values=lrep[..., 3:].reshape(N, B, Wr, sl.VALUE_WORDS),
        lock_fail=(status == R.ST_LOCK_FAIL) & en,
        # overflow-class outcomes: dropped by back-pressure (retryable) or
        # table full (ST_NO_SPACE, delivered) — both abort with cause overflow
        no_space=((status == R.ST_NO_SPACE) | (status == R.ST_DROPPED)
                  | lovf) & en,
        overflow=lovf & en, wire=s_lock)


def validate_read_set(t: Transport, state, layout, read_ctx, *,
                      capacity: Optional[int] = None):
    """VALIDATE phase: one-sided re-read of every read-set slot version.

    Returns a dict with per-item `valid` plus the overflow mask and wire
    stats.  Absent reads validate trivially (repeatable-read of a miss is NOT
    guaranteed — documented limitation, same as the paper's protocol sketch).
    """
    # absent reads validate trivially, so only found reads are re-read — dead
    # validation reads would waste per-destination send-queue capacity and
    # could overflow a found lane's re-read for nothing
    issued = read_ctx["enabled"] & read_ctx["found"]
    voff = ht.slot_idx_offset(layout, read_ctx["slot"])
    vbuf, vovf, s_val = osd.remote_read(
        t, state["arena"], read_ctx["node"], voff, length=sl.SLOT_WORDS,
        capacity=capacity, enabled=issued)
    cur_ver = vbuf[..., sl.VERSION]
    cur_klo = vbuf[..., sl.KEY_LO]
    cur_lock = vbuf[..., sl.LOCK]
    unchanged = ((cur_ver == read_ctx["versions"])
                 & (cur_klo == read_ctx["key_lo"]) & (cur_lock == 0) & ~vovf)
    valid = unchanged | ~read_ctx["found"]
    return dict(valid=valid, overflow=vovf & issued, wire=s_val)


def commit_or_abort(t: Transport, state, serial_h, lock_ctx, *, commit_lane,
                    write_values, capacity: Optional[int] = None):
    """COMMIT / ABORT phase: lanes that hold locks either install their values
    (version += 2, unlock) or roll back.  commit_lane: (N, B) bool;
    write_values: anything reshapeable to (N, B*Wr, VALUE_WORDS).

    This round cannot overflow: its enabled set (lock holders) is a subset of
    the lanes the lock round DELIVERED, to the same destinations in the same
    lane order at the same capacity, so every enabled lane's send-queue rank
    can only shrink.  That invariant is what guarantees an acquired lock is
    always released — run_transactions still folds the returned overflow into
    the abort classification as defense in depth."""
    N, B = commit_lane.shape
    Wr = lock_ctx["key_lo"].shape[1] // max(B, 1)
    commit_item = jnp.repeat(commit_lane, Wr, axis=-1)  # (N, B*Wr)
    op = jnp.where(commit_item, jnp.uint32(R.OP_COMMIT_UNLOCK),
                   jnp.uint32(R.OP_ABORT_UNLOCK))
    cm_recs = ht.make_record(
        op, lock_ctx["key_lo"], lock_ctx["key_hi"], aux=lock_ctx["lock_slot"],
        value=write_values.reshape(N, B * Wr, sl.VALUE_WORDS))
    # only lanes that actually HOLD a lock must unlock/commit
    state, crep, covf, s_cm = R.rpc_call(
        t, state, lock_ctx["node"], cm_recs, serial_h, capacity=capacity,
        enabled=lock_ctx["lock_ok"])
    return state, dict(overflow=covf & lock_ctx["lock_ok"], wire=s_cm)


def run_transactions(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
                     read_keys, write_keys, write_values, write_enabled=None,
                     read_enabled=None, cache=None, use_onesided: bool = True,
                     capacity: Optional[int] = None):
    """Execute a batch of transactions, one per lane (single shot — aborted
    lanes report their cause and stop; see txloop.tx_loop for bounded retry).

    read_keys:    (N, B, Rd, 2) uint32 (lo, hi)
    write_keys:   (N, B, Wr, 2) uint32
    write_values: (N, B, Wr, VALUE_WORDS) uint32
    *_enabled:    optional masks (N, B, Rd/Wr) for ragged sets.

    Read/write sets are assumed disjoint per lane (read-for-update goes in the
    write set — its LOCK reply returns the current value, Fig. 3).
    """
    N, B, Rd = read_keys.shape[:3]
    Wr = write_keys.shape[2]
    if read_enabled is None:
        read_enabled = jnp.ones((N, B, Rd), bool)
    if write_enabled is None:
        write_enabled = jnp.ones((N, B, Wr), bool)
    serial_h = ht.make_rpc_handler(cfg, layout)

    # ---------------- EXECUTE: read set (hybrid one-two-sided) -------------
    state, cache, rctx = execute_read_set(
        t, state, cfg, layout, read_keys=read_keys, read_enabled=read_enabled,
        cache=cache, use_onesided=use_onesided, capacity=capacity)
    m = rctx["metrics"]
    read_found = rctx["found"].reshape(N, B, Rd)

    # ---------------- EXECUTE: lock + read-for-update the write set --------
    state, lctx = lock_write_set(
        t, state, cfg, layout, serial_h, write_keys=write_keys,
        write_enabled=write_enabled, capacity=capacity)
    lane_locks_ok = jnp.all(
        (lctx["lock_ok"] | ~lctx["enabled"]).reshape(N, B, Wr), axis=-1)

    # ---------------- VALIDATE: one-sided re-read of read-set versions -----
    vctx = validate_read_set(t, state, layout, rctx, capacity=capacity)
    lane_valid = jnp.all(
        (vctx["valid"] | ~rctx["enabled"]).reshape(N, B, Rd), axis=-1)

    # a read dropped by back-pressure is NOT a miss: the lane must abort
    # (cause: overflow) and retry, never commit against an unread read set
    lane_reads_ok = ~jnp.any(rctx["overflow"].reshape(N, B, Rd), axis=-1)

    # ---------------- COMMIT / ABORT (write-based RPCs) --------------------
    commit_lane = lane_locks_ok & lane_valid & lane_reads_ok    # (N, B)
    state, cctx = commit_or_abort(
        t, state, serial_h, lctx, commit_lane=commit_lane,
        write_values=write_values, capacity=capacity)

    has_writes = jnp.any(write_enabled, axis=-1)
    # commit RPCs provably never overflow (see commit_or_abort); the gate is
    # defense in depth so a lost commit could never masquerade as success
    commit_delivered = ~jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1)
    committed = jnp.where(has_writes, commit_lane & commit_delivered,
                          lane_valid & lane_reads_ok)

    # ---------------- abort causes (priority: overflow > lock > validate) --
    lane_ovf = (~lane_reads_ok
                | jnp.any(lctx["no_space"].reshape(N, B, Wr), axis=-1)
                | jnp.any(vctx["overflow"].reshape(N, B, Rd), axis=-1)
                | jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1))
    lane_lock_fail = jnp.any(lctx["lock_fail"].reshape(N, B, Wr), axis=-1)
    aborted = ~committed
    aborted_overflow = aborted & lane_ovf
    aborted_lock = aborted & ~lane_ovf & lane_lock_fail
    aborted_validate = aborted & ~lane_ovf & ~lane_lock_fail & ~lane_valid

    wire = (m.wire + lctx["wire"] + vctx["wire"] + cctx["wire"])
    metrics = hy.HybridMetrics(
        onesided_success=m.onesided_success,
        rpc_fallback=m.rpc_fallback,
        total=m.total,
        wire=wire,
    )
    rts = (m.wire.round_trips + lctx["wire"].round_trips
           + vctx["wire"].round_trips + cctx["wire"].round_trips)
    return state, cache, TxResult(
        committed=committed,
        read_found=read_found,
        read_values=rctx["values"].reshape(N, B, Rd, sl.VALUE_WORDS),
        locked_values=lctx["locked_values"],
        aborted_lock=aborted_lock,
        aborted_validate=aborted_validate,
        aborted_overflow=aborted_overflow,
        metrics=metrics,
        round_trips=rts,
    )
