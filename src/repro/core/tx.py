"""Storm transactional protocol (§5.4, Fig. 3): OCC + 2PC optimized for the
dataplane's two primitives.

Per transaction lane:
  EXECUTE   read-set via one-two-sided hybrid lookups (reads buffered
            locally), write-set read-for-update + LOCK via write-based RPC
            (the paper locks intended writes during execution).
  VALIDATE  re-read read-set slot versions with ONE-SIDED reads (Storm keeps
            the remote offsets of every read-set object).
  COMMIT    write-based RPCs install values, bump versions to even, unlock.
  ABORT     unlock / roll back placeholder inserts for lanes whose locks
            failed or whose validation detected a concurrent writer.

Shapes are static: each lane has exactly R read keys and W write keys; lanes
are batched B per node ("coroutines"), so a full transaction costs the same
FIVE pipeline rounds the paper's Figure 3 shows, independent of B:
    read (1-2 RTs: read + masked RPC) + lock (1) + validate (1) + commit (1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hybrid as hy
from repro.core import onesided as osd
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport, WireStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxResult:
    committed: jnp.ndarray        # (N, B) bool
    read_found: jnp.ndarray       # (N, B, R) bool
    read_values: jnp.ndarray      # (N, B, R, VALUE_WORDS)
    locked_values: jnp.ndarray    # (N, B, W, VALUE_WORDS) read-for-update values
    metrics: hy.HybridMetrics
    round_trips: jnp.ndarray      # scalar


def run_transactions(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
                     read_keys, write_keys, write_values, write_enabled=None,
                     read_enabled=None, cache=None, use_onesided: bool = True,
                     capacity: Optional[int] = None):
    """Execute a batch of transactions, one per lane.

    read_keys:    (N, B, Rd, 2) uint32 (lo, hi)
    write_keys:   (N, B, Wr, 2) uint32
    write_values: (N, B, Wr, VALUE_WORDS) uint32
    *_enabled:    optional masks (N, B, Rd/Wr) for ragged sets.

    Read/write sets are assumed disjoint per lane (read-for-update goes in the
    write set — its LOCK reply returns the current value, Fig. 3).
    """
    N, B, Rd = read_keys.shape[:3]
    Wr = write_keys.shape[2]
    if read_enabled is None:
        read_enabled = jnp.ones((N, B, Rd), bool)
    if write_enabled is None:
        write_enabled = jnp.ones((N, B, Wr), bool)
    serial_h = ht.make_rpc_handler(cfg, layout)
    wire = WireStats.zero()

    # ---------------- EXECUTE: read set (hybrid one-two-sided) -------------
    rk_lo = read_keys[..., 0].reshape(N, B * Rd)
    rk_hi = read_keys[..., 1].reshape(N, B * Rd)
    state, cache, found, rvals, rvers, rnode, rslot, m = hy.hybrid_lookup(
        t, state, rk_lo, rk_hi, cfg, layout, cache=cache,
        use_onesided=use_onesided, rpc_serial=False, capacity=capacity)
    wire = wire + m.wire
    read_found = (found & read_enabled.reshape(N, B * Rd)).reshape(N, B, Rd)

    # ---------------- EXECUTE: lock + read-for-update the write set --------
    wk_lo = write_keys[..., 0].reshape(N, B * Wr)
    wk_hi = write_keys[..., 1].reshape(N, B * Wr)
    wnode, _, _ = ht.lookup_start(cfg, layout, wk_lo, wk_hi, None)
    # unique nonzero lock tag per (node, lane)
    lane = jnp.arange(B * Wr, dtype=jnp.uint32) // jnp.uint32(Wr)
    tag = (t.node_ids().astype(jnp.uint32)[:, None] * jnp.uint32(B)
           + lane[None, :] + jnp.uint32(1))
    lock_recs = ht.make_record(R.OP_LOCK, wk_lo, wk_hi, aux=tag)
    state, lrep, lovf, s_lock = R.rpc_call(
        t, state, wnode, lock_recs, serial_h, capacity=capacity,
        enabled=write_enabled.reshape(N, B * Wr))
    wire = wire + s_lock
    lock_ok = (lrep[..., 0] == R.ST_OK) & ~lovf
    lock_slot = lrep[..., 1]
    locked_values = lrep[..., 3:].reshape(N, B, Wr, sl.VALUE_WORDS)
    lane_locks_ok = jnp.all(
        (lock_ok | ~write_enabled.reshape(N, B * Wr)).reshape(N, B, Wr), axis=-1)

    # ---------------- VALIDATE: one-sided re-read of read-set versions -----
    voff = ht.slot_idx_offset(layout, rslot)
    vbuf, vovf, s_val = osd.remote_read(
        t, state["arena"], rnode, voff, length=sl.SLOT_WORDS, capacity=capacity)
    cur_ver = vbuf[..., sl.VERSION]
    cur_klo = vbuf[..., sl.KEY_LO]
    cur_lock = vbuf[..., sl.LOCK]
    unchanged = (cur_ver == rvers) & (cur_klo == rk_lo) & (cur_lock == 0) & ~vovf
    # absent reads validate trivially (repeatable-read of a miss is NOT
    # guaranteed — documented limitation, same as the paper's protocol sketch)
    read_valid = unchanged | ~found
    wire = wire + s_val
    lane_valid = jnp.all(
        (read_valid | ~read_enabled.reshape(N, B * Rd)).reshape(N, B, Rd), axis=-1)

    # ---------------- COMMIT / ABORT (write-based RPCs) --------------------
    commit_lane = lane_locks_ok & lane_valid            # (N, B)
    commit_item = jnp.repeat(commit_lane, Wr, axis=-1)  # (N, B*Wr)
    op = jnp.where(commit_item, jnp.uint32(R.OP_COMMIT_UNLOCK),
                   jnp.uint32(R.OP_ABORT_UNLOCK))
    cm_recs = ht.make_record(
        op, wk_lo, wk_hi, aux=lock_slot,
        value=write_values.reshape(N, B * Wr, sl.VALUE_WORDS))
    # only lanes that actually HOLD a lock must unlock/commit
    state, crep, covf, s_cm = R.rpc_call(
        t, state, wnode, cm_recs, serial_h, capacity=capacity,
        enabled=lock_ok & write_enabled.reshape(N, B * Wr))
    wire = wire + s_cm

    has_writes = jnp.any(write_enabled, axis=-1)
    committed = jnp.where(has_writes, commit_lane, lane_valid)

    metrics = hy.HybridMetrics(
        onesided_success=m.onesided_success,
        rpc_fallback=m.rpc_fallback,
        total=m.total,
        wire=wire,
    )
    rts = m.wire.round_trips + s_lock.round_trips + s_val.round_trips + s_cm.round_trips
    return state, cache, TxResult(
        committed=committed,
        read_found=read_found,
        read_values=rvals.reshape(N, B, Rd, sl.VALUE_WORDS),
        locked_values=locked_values,
        metrics=metrics,
        round_trips=rts,
    )
