"""Storm transactional protocol (§5.4, Fig. 3): OCC + 2PC optimized for the
dataplane's two primitives.

Per transaction lane:
  EXECUTE   read-set via one-two-sided hybrid lookups (reads buffered
            locally), write-set read-for-update + LOCK via write-based RPC
            (the paper locks intended writes during execution).
  VALIDATE  re-read read-set slot versions with ONE-SIDED reads (Storm keeps
            the remote offsets of every read-set object).
  COMMIT    write-based RPCs install values, bump versions to even, unlock.
  ABORT     unlock / roll back placeholder inserts for lanes whose locks
            failed or whose validation detected a concurrent writer.

Shapes are static: each lane has exactly R read keys and W write keys; lanes
are batched B per node ("coroutines").

Two schedules share every phase's records, handlers and decision logic:

  * ``run_transactions(fused=False)`` — the per-phase reference: FIVE
    exchange rounds (one-sided read, RPC fallback, lock, validate, commit),
    one phase per all-to-all, exactly Figure 3 drawn naively.
  * ``run_transactions(fused=True)`` (default) — the fused schedule built on
    roundsched.fused_round.  The read-set RPC fallback is independent of
    LOCK, and the validate re-read of every lane whose slot address the
    one-sided read already learned only needs to observe the post-lock
    state — so both ride the lock round:

        round 1  one-sided read of the read set
        round 2  fallback lookups ∥ LOCK ∥ validate(one-sided hits)
        round 3  validate(addresses learned via RPC)      [empty on the
                 one-sided fast path — costs no round trip]
        round 4  commit / abort

    i.e. **4 exchange rounds in the general case, 3 when every read-set
    lookup is satisfied one-sided** — versus 5 for the reference, with
    bit-identical committed state, abort causes and delivered-request counts
    (see tests/test_tx_fused_equivalence.py).

Aborts are classified by cause — lock conflict, validation conflict, or
overflow/back-pressure — which is what the retry loop (txloop.tx_loop) and
the contention benchmarks report.

With a ``rep=replication.ReplicaConfig(f > 0)``, COMMIT installs the write
set on all f+1 copies: the backup writes ride the commit fused round as
extra traffic classes (zero additional exchange rounds, wider commit
fan-out; see commit_or_abort).

Public API: ``run_transactions`` (single shot) + ``TxResult``, and the
per-phase functions ``execute_read_set`` / ``lock_write_set`` /
``validate_read_set`` / ``commit_or_abort`` the reference schedule is built
from.  Invariants: ``fused=True`` is round-count-only (committed state,
abort causes and WireStats.ops are bit-identical to ``fused=False``);
``rep=None`` and ``rep.f == 0`` are bit-identical to each other.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hybrid as hy
from repro.core import onesided as osd
from repro.core import placement as pl
from repro.core import regions as rg
from repro.core import replication as repl
from repro.core import roundsched as rs
from repro.core import rpc as R
from repro.core import telemetry as T
from repro.core import wireproto as W
from repro.core import slots as sl
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.transport import Transport


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TxResult:
    committed: jnp.ndarray        # (N, B) bool
    read_found: jnp.ndarray       # (N, B, R) bool
    read_values: jnp.ndarray      # (N, B, R, VALUE_WORDS)
    locked_values: jnp.ndarray    # (N, B, W, VALUE_WORDS) read-for-update values
    aborted_lock: jnp.ndarray     # (N, B) bool — lost a lock race
    aborted_validate: jnp.ndarray  # (N, B) bool — read-set changed underfoot
    aborted_overflow: jnp.ndarray  # (N, B) bool — back-pressure / no space
    aborted_stale: jnp.ndarray    # (N, B) bool — routed by a stale placement
                                  # table (ST_WRONG_EPOCH): refresh + retry
    metrics: hy.HybridMetrics
    round_trips: jnp.ndarray      # scalar


# ---------------------------------------------------------------------------
# Shared request construction / reply parsing.  Both schedules build records
# and decode replies through these helpers, so they are equivalent by
# construction at the record level.
# ---------------------------------------------------------------------------
def _lock_requests(t: Transport, cfg: ht.HashTableConfig, layout, *,
                   write_keys, write_enabled, ptable=None):
    """Flatten the write set and build the OP_LOCK records (+ unique tags).

    With a ``ptable`` (placement.PlacementTable), lock-class ops route to the
    partition OWNER — never a backup, so a lane can never fake a grant at a
    replica: a dead owner parks the lane (dest -1 -> ST_DROPPED -> abort
    overflow) until repair promotes a backup.  The lane stays ENABLED —
    masking it instead would make the all-locks-held conjunction vacuously
    true and commit an unlocked write set."""
    N, B, Wr = write_keys.shape[:3]
    wk_lo = write_keys[..., 0].reshape(N, B * Wr)
    wk_hi = write_keys[..., 1].reshape(N, B * Wr)
    en = write_enabled.reshape(N, B * Wr)
    part = ht.part_of(cfg, wk_lo, wk_hi)
    if ptable is None:
        wnode, _, _ = ht.lookup_start(cfg, layout, wk_lo, wk_hi, None)
    else:
        wnode = pl.owner_dest(ptable, part)
    # unique nonzero lock tag per (node, lane)
    lane = jnp.arange(B * Wr, dtype=jnp.uint32) // jnp.uint32(max(Wr, 1))
    tag = (t.node_ids().astype(jnp.uint32)[:, None] * jnp.uint32(B)
           + lane[None, :] + jnp.uint32(1))
    recs = ht.make_record(W.OP_LOCK, wk_lo, wk_hi, aux=tag)
    return dict(key_lo=wk_lo, key_hi=wk_hi, enabled=en, node=wnode, tag=tag,
                part=part), recs


def _parse_lock_replies(lk, lrep, lovf, N, B, Wr):
    """Decode the LOCK round's replies into the lock context dict."""
    status = lrep[..., 0]
    en = lk["enabled"]
    lock_ok = (status == W.ST_OK) & ~lovf & en
    return dict(
        lk,
        lock_ok=lock_ok, lock_slot=lrep[..., 1],
        # version at lock time (even, also for lock-inserted placeholders) —
        # the committed version every copy will carry is (lock_ver | 1) + 1,
        # which is what the backup fan-out installs (replication module)
        lock_ver=lrep[..., 2],
        locked_values=lrep[..., 3:].reshape(N, B, Wr, sl.VALUE_WORDS),
        lock_fail=(status == W.ST_LOCK_FAIL) & en,
        # the routing table this lane used is stale: the addressed node no
        # longer owns the key's partition (abort cause stale_route — txloop
        # refreshes the table and retries)
        stale=(status == W.ST_WRONG_EPOCH) & en,
        # overflow-class outcomes: dropped by back-pressure (retryable) or
        # table full (ST_NO_SPACE, delivered) — both abort with cause overflow
        no_space=((status == W.ST_NO_SPACE) | (status == W.ST_DROPPED)
                  | lovf) & en,
        overflow=lovf & en)


def _validate_from_bytes(read_ctx, vbuf, vovf):
    """Shared VALIDATE decision: compare re-read slot bytes against the
    execute-phase observation.  Absent reads validate trivially
    (repeatable-read of a miss is NOT guaranteed — documented limitation,
    same as the paper's protocol sketch)."""
    cur_ver = vbuf[..., sl.VERSION]
    cur_klo = vbuf[..., sl.KEY_LO]
    cur_lock = vbuf[..., sl.LOCK]
    unchanged = ((cur_ver == read_ctx["versions"])
                 & (cur_klo == read_ctx["key_lo"]) & (cur_lock == 0) & ~vovf)
    issued = read_ctx["enabled"] & read_ctx["found"]
    return dict(valid=unchanged | ~read_ctx["found"], overflow=vovf & issued)


# ---------------------------------------------------------------------------
# Phase functions (the per-phase reference schedule).  Each takes/returns
# cluster state plus a plain dict of per-item arrays; lane axes are flattened
# to (N, B*K) like the wire sees them.
# ---------------------------------------------------------------------------
def execute_read_set(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
                     read_keys, read_enabled, cache=None,
                     use_onesided: bool = True, capacity: Optional[int] = None,
                     nic=None, ptable=None, telemetry=None):
    """EXECUTE phase, read half: one-two-sided lookups of the read set.

    read_keys: (N, B, Rd, 2); read_enabled: (N, B, Rd) bool.
    Returns (state, cache, ctx) where ctx holds the flattened (N, B*Rd)
    found/values/versions/owner/slot arrays the later phases need.
    """
    N, B, Rd = read_keys.shape[:3]
    rk_lo = read_keys[..., 0].reshape(N, B * Rd)
    rk_hi = read_keys[..., 1].reshape(N, B * Rd)
    en = read_enabled.reshape(N, B * Rd)
    state, cache, found, rvals, rvers, rnode, rslot, rovf, m = hy.hybrid_lookup(
        t, state, rk_lo, rk_hi, cfg, layout, cache=cache,
        use_onesided=use_onesided, rpc_serial=False, capacity=capacity,
        enabled=en, nic=nic, ptable=ptable, telemetry=telemetry)
    return state, cache, dict(
        key_lo=rk_lo, key_hi=rk_hi, enabled=en, found=found, values=rvals,
        versions=rvers, node=rnode, slot=rslot, overflow=rovf, metrics=m)


def lock_write_set(t: Transport, state, cfg: ht.HashTableConfig, layout,
                   serial_h, *, write_keys, write_enabled,
                   capacity: Optional[int] = None, nic=None, ptable=None,
                   telemetry=None):
    """EXECUTE phase, write half: LOCK + read-for-update the write set.

    write_keys: (N, B, Wr, 2); write_enabled: (N, B, Wr) bool.
    """
    N, B, Wr = write_keys.shape[:3]
    lk, lock_recs = _lock_requests(t, cfg, layout, write_keys=write_keys,
                                   write_enabled=write_enabled, ptable=ptable)
    state, lrep, lovf, s_lock = R.rpc_call(
        t, state, lk["node"], lock_recs, serial_h, capacity=capacity,
        enabled=lk["enabled"], nic=nic, telemetry=telemetry, phase=T.PH_LOCK)
    lctx = _parse_lock_replies(lk, lrep, lovf, N, B, Wr)
    lctx["wire"] = s_lock
    return state, lctx


def validate_read_set(t: Transport, state, layout, read_ctx, *,
                      capacity: Optional[int] = None, nic=None,
                      offset_of=None, telemetry=None):
    """VALIDATE phase: one-sided re-read of every read-set slot version.

    ``offset_of(layout, slot_idx)`` maps a read-set slot index to its arena
    word offset (default: the hash table's ``slots`` region; the ordered
    index validates leaf HEADER slots in its ``leaves`` region instead).
    Returns a dict with per-item `valid` plus the overflow mask and wire
    stats."""
    # absent reads validate trivially, so only found reads are re-read — dead
    # validation reads would waste per-destination send-queue capacity and
    # could overflow a found lane's re-read for nothing
    issued = read_ctx["enabled"] & read_ctx["found"]
    if offset_of is None:
        offset_of = ht.slot_idx_offset
    voff = offset_of(layout, read_ctx["slot"])
    vbuf, vovf, s_val = osd.remote_read(
        t, state["arena"], read_ctx["node"], voff, length=sl.SLOT_WORDS,
        capacity=capacity, enabled=issued, nic=nic, telemetry=telemetry,
        phase=T.PH_VALIDATE)
    vctx = _validate_from_bytes(read_ctx, vbuf, vovf)
    vctx["wire"] = s_val
    return vctx


def _backup_dest(lock_ctx, rep, i, ptable):
    """Destination of backup copy ``i`` for each write item.

    Without a placement table this is the ring rotation off the LOCK
    destination (the pre-placement dataplane, bit-identical).  With one, the
    copy list comes from the table's row for the item's PARTITION — which is
    what keeps the commit fan-out correct after a migration or repair has
    re-homed the partition.  A dead or absent copy slot routes to -1: the
    transport parks the record, the lane aborts (cause overflow) and retries
    until repair re-points the copy — never a silent under-replication."""
    if ptable is None:
        return rep.replica_of(lock_ctx["node"], i)
    cand = pl.copy_nodes(ptable, lock_ctx["part"])[..., i]
    ok = (cand >= 0) & ptable.alive[
        jnp.clip(cand, 0, ptable.alive.shape[0] - 1)]
    return jnp.where(ok, cand, -1).astype(jnp.int32)


def commit_or_abort(t: Transport, state, serial_h, lock_ctx, *, commit_lane,
                    write_values, capacity: Optional[int] = None, nic=None,
                    rep=None, ptable=None, telemetry=None):
    """COMMIT / ABORT phase: lanes that hold locks either install their values
    (version += 2, unlock) or roll back.  commit_lane: (N, B) bool;
    write_values: anything reshapeable to (N, B*Wr, VALUE_WORDS).

    With replication (rep = replication.ReplicaConfig, f > 0), each of the f
    backup copies rides this SAME fused round as an extra OP_BACKUP_WRITE
    traffic class headed for replica_of(primary, i) — the commit round fans
    out wider (more (src, dst) pairs on the wire) but the schedule gains ZERO
    exchange rounds.  Aborting lanes release their locks and install nothing
    anywhere.

    The primary class cannot overflow: its enabled set (lock holders) is a
    subset of the lanes the lock round DELIVERED, to the same destinations in
    the same lane order at the same capacity, so every enabled lane's
    send-queue rank can only shrink.  That invariant is what guarantees an
    acquired lock is always released.  The ring-rotation backup classes
    inherit it — the rotation is a bijection on destinations, so no backup
    destination receives more records than some primary destination did — but
    a non-bijective placement (or a future placement change) CAN overflow, so
    every backup class's per-lane overflow mask (and any delivered-but-full
    ST_NO_SPACE reply) is folded into the abort classification: a dropped
    backup write aborts its lane (cause: overflow) for txloop to retry,
    never silently degrading the record to fewer than f+1 copies.

    Documented limitation of the single-round fan-out: the primary cannot
    observe its backups' outcome within the round, so a commit whose backup
    write failed has ALREADY installed the primary copy (lock released) when
    the lane reports aborted_overflow.  The retry reinstalls the same value
    idempotently and the lane converges to committed as soon as the backup
    accepts (tests/test_replication.py exercises the drain); only a
    PERMANENTLY full backup table leaves the lane reporting aborted with its
    primary copy visible — the capacity-exhaustion regime ST_NO_SPACE exists
    to signal, to be provisioned for exactly like the primary tables (whose
    exhaustion aborts cleanly at LOCK time)."""
    N, B = commit_lane.shape
    Wr = lock_ctx["key_lo"].shape[1] // max(B, 1)
    commit_item = jnp.repeat(commit_lane, Wr, axis=-1)  # (N, B*Wr)
    op = jnp.where(commit_item, jnp.uint32(W.OP_COMMIT_UNLOCK),
                   jnp.uint32(W.OP_ABORT_UNLOCK))
    # the key_lo word carries the lock tag: the owner releases a lock only
    # for the exact tag that acquired it (hashtable's unlock ownership check)
    cm_recs = ht.make_record(
        op, lock_ctx["tag"], lock_ctx["key_hi"], aux=lock_ctx["lock_slot"],
        value=write_values.reshape(N, B * Wr, sl.VALUE_WORDS))
    # only lanes that actually HOLD a lock must unlock/commit
    classes = [rs.rpc_class(lock_ctx["node"], cm_recs, serial_h,
                            enabled=lock_ctx["lock_ok"], capacity=capacity)]
    bk_en = None
    if rep is not None and rep.f > 0:
        bk_recs = repl.backup_write_records(lock_ctx, write_values)
        # only COMMITTING lock holders install backups (aborts touch nothing)
        bk_en = commit_item & lock_ctx["lock_ok"]
        for i in range(1, rep.f + 1):
            classes.append(rs.rpc_class(
                _backup_dest(lock_ctx, rep, i, ptable), bk_recs, serial_h,
                enabled=bk_en, capacity=capacity))
    state, results, s_cm = rs.fused_round(t, state, classes, nic=nic,
                                          telemetry=telemetry,
                                          phase=T.PH_COMMIT)
    overflow = results[0][1] & lock_ctx["lock_ok"]
    for brep, bovf in results[1:]:
        overflow = overflow | ((bovf | (brep[..., 0] == W.ST_NO_SPACE))
                               & bk_en)
    return state, dict(overflow=overflow, wire=s_cm)


# ---------------------------------------------------------------------------
# Shared tail: commit decision, abort classification, result packing.
# ---------------------------------------------------------------------------
def _decide_and_finish(t, state, serial_h, *, N, B, Rd, Wr, write_enabled,
                       write_values, rctx, lctx, vctx, read_wire,
                       onesided_success, rpc_fallback, total,
                       capacity, nic=None, rep=None, ptable=None,
                       telemetry=None):
    lane_locks_ok = jnp.all(
        (lctx["lock_ok"] | ~lctx["enabled"]).reshape(N, B, Wr), axis=-1)
    lane_valid = jnp.all(
        (vctx["valid"] | ~rctx["enabled"]).reshape(N, B, Rd), axis=-1)
    # a read dropped by back-pressure is NOT a miss: the lane must abort
    # (cause: overflow) and retry, never commit against an unread read set
    lane_reads_ok = ~jnp.any(rctx["overflow"].reshape(N, B, Rd), axis=-1)

    # ---------------- COMMIT / ABORT (write-based RPCs) --------------------
    commit_lane = lane_locks_ok & lane_valid & lane_reads_ok    # (N, B)
    state, cctx = commit_or_abort(
        t, state, serial_h, lctx, commit_lane=commit_lane,
        write_values=write_values, capacity=capacity, nic=nic, rep=rep,
        ptable=ptable, telemetry=telemetry)

    has_writes = jnp.any(write_enabled, axis=-1)
    # commit RPCs provably never overflow (see commit_or_abort); the gate is
    # defense in depth so a lost commit could never masquerade as success
    commit_delivered = ~jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1)
    committed = jnp.where(has_writes, commit_lane & commit_delivered,
                          lane_valid & lane_reads_ok)

    # -------- abort causes (priority: overflow > stale > lock > validate) --
    lane_ovf = (~lane_reads_ok
                | jnp.any(lctx["no_space"].reshape(N, B, Wr), axis=-1)
                | jnp.any(vctx["overflow"].reshape(N, B, Rd), axis=-1)
                | jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1))
    lane_stale = jnp.any(lctx["stale"].reshape(N, B, Wr), axis=-1)
    lane_lock_fail = jnp.any(lctx["lock_fail"].reshape(N, B, Wr), axis=-1)
    aborted = ~committed
    aborted_overflow = aborted & lane_ovf
    aborted_stale = aborted & ~lane_ovf & lane_stale
    aborted_lock = aborted & ~lane_ovf & ~lane_stale & lane_lock_fail
    aborted_validate = (aborted & ~lane_ovf & ~lane_stale & ~lane_lock_fail
                        & ~lane_valid)

    wire = read_wire + lctx["wire"] + vctx["wire"] + cctx["wire"]
    metrics = hy.HybridMetrics(
        onesided_success=onesided_success,
        rpc_fallback=rpc_fallback,
        total=total,
        wire=wire,
    )
    rts = (read_wire.round_trips + lctx["wire"].round_trips
           + vctx["wire"].round_trips + cctx["wire"].round_trips)
    return state, TxResult(
        committed=committed,
        read_found=rctx["found"].reshape(N, B, Rd),
        read_values=rctx["values"].reshape(N, B, Rd, sl.VALUE_WORDS),
        locked_values=lctx["locked_values"],
        aborted_lock=aborted_lock,
        aborted_validate=aborted_validate,
        aborted_overflow=aborted_overflow,
        aborted_stale=aborted_stale,
        metrics=metrics,
        round_trips=rts,
    )


# ---------------------------------------------------------------------------
# The fused schedule (roundsched.fused_round): 3-4 exchange rounds.
# ---------------------------------------------------------------------------
def _run_transactions_fused(t: Transport, state, cfg, layout, *, read_keys,
                            write_keys, write_values, write_enabled,
                            read_enabled, cache, use_onesided, capacity,
                            nic=None, rep=None, ptable=None, telemetry=None):
    N, B, Rd = read_keys.shape[:3]
    Wr = write_keys.shape[2]
    serial_h = ht.make_rpc_handler(cfg, layout)
    rk_lo = read_keys[..., 0].reshape(N, B * Rd)
    rk_hi = read_keys[..., 1].reshape(N, B * Rd)
    ren = read_enabled.reshape(N, B * Rd)

    # ---- round 1: one-sided read of the read set --------------------------
    probe = hy.onesided_probe(t, state, rk_lo, rk_hi, cfg, layout, cache=cache,
                              use_onesided=use_onesided, capacity=capacity,
                              enabled=ren, nic=nic, ptable=ptable,
                              telemetry=telemetry)

    # ---- round 2: read-set RPC fallback ∥ LOCK ∥ validate(one-sided hits) -
    # The fallback is independent of LOCK (different key sets, the lookup is
    # read-only and observes the round's pre-handler state); the validate
    # re-read of a lane whose slot address round 1 already learned only needs
    # to observe the post-lock state, which the fused round's gather-last
    # ordering provides.  Under an explicit capacity bound the validate phase
    # keeps its own round instead, so its send-queue back-pressure policy
    # stays bit-identical to the reference's single validate round.
    lk, lock_recs = _lock_requests(t, cfg, layout, write_keys=write_keys,
                                   write_enabled=write_enabled, ptable=ptable)
    lookup_recs = ht.make_record(W.OP_LOOKUP, rk_lo, rk_hi)
    vector_h = ht.make_lookup_handler_vector(cfg, layout)
    classes = [
        rs.rpc_class(probe["node"], lookup_recs, vector_h,
                     enabled=probe["need_rpc"], capacity=capacity),
        rs.rpc_class(lk["node"], lock_recs, serial_h, enabled=lk["enabled"],
                     capacity=capacity),
    ]
    fuse_v1 = capacity is None and Rd > 0
    if fuse_v1:
        classes.append(rs.read_class(
            probe["node"], ht.slot_idx_offset(layout, probe["slot_idx"]),
            length=sl.SLOT_WORDS, enabled=ren & probe["success"]))
    state, results, s2 = rs.fused_round(t, state, classes, nic=nic,
                                        telemetry=telemetry, phase=T.PH_LOCK)
    lookup_rep, lookup_ovf = results[0]
    lrep, lovf = results[1]

    lctx = _parse_lock_replies(lk, lrep, lovf, N, B, Wr)
    mg = hy.merge_rpc_fallback(probe, lookup_rep, lookup_ovf)
    cache = hy.update_lookup_cache(cfg, cache, rk_lo, rk_hi, probe["node"],
                                   mg["slot_idx"], mg["found"])
    rctx = dict(key_lo=rk_lo, key_hi=rk_hi, enabled=ren, found=mg["found"],
                values=mg["value"], versions=mg["version"],
                node=probe["node"], slot=mg["slot_idx"],
                overflow=mg["overflow"])

    # ---- round 3: validate re-reads whose address came from the RPC -------
    # (empty — and therefore free of wire cost — on the one-sided fast path)
    if fuse_v1:
        v1buf = results[2][0]
        v2buf, _, s3 = osd.remote_read(
            t, state["arena"], probe["node"],
            ht.slot_idx_offset(layout, mg["slot_idx"]), length=sl.SLOT_WORDS,
            enabled=ren & mg["rpc_ok"], nic=nic, telemetry=telemetry,
            phase=T.PH_VALIDATE)
        vbuf = jnp.where(probe["success"][..., None], v1buf, v2buf)
        # without a capacity bound neither validate sub-round can overflow
        vctx = _validate_from_bytes(rctx, vbuf, jnp.zeros((N, B * Rd), bool))
        vctx["wire"] = s3
    else:
        vctx = validate_read_set(t, state, layout, rctx, capacity=capacity,
                                 nic=nic, telemetry=telemetry)

    # the lock round's wire is fused into s2; attribute the whole fused round
    # to the lock slot of the accounting so totals stay exact
    lctx["wire"] = s2

    state, res = _decide_and_finish(
        t, state, serial_h, N=N, B=B, Rd=Rd, Wr=Wr,
        write_enabled=write_enabled, write_values=write_values,
        rctx=rctx, lctx=lctx, vctx=vctx, read_wire=probe["wire"],
        onesided_success=jnp.sum(probe["success"].astype(jnp.float32)),
        rpc_fallback=jnp.sum(probe["need_rpc"].astype(jnp.float32)),
        total=jnp.sum(ren.astype(jnp.float32)),
        capacity=capacity, nic=nic, rep=rep, ptable=ptable,
        telemetry=telemetry)
    return state, cache, res


def run_transactions(t: Transport, state, cfg: ht.HashTableConfig, layout, *,
                     read_keys, write_keys, write_values, write_enabled=None,
                     read_enabled=None, cache=None, use_onesided: bool = True,
                     capacity: Optional[int] = None, fused: bool = True,
                     nic=None, rep=None, ptable=None, telemetry=None):
    """Execute a batch of transactions, one per lane (single shot — aborted
    lanes report their cause and stop; see txloop.tx_loop for bounded retry).

    read_keys:    (N, B, Rd, 2) uint32 (lo, hi)
    write_keys:   (N, B, Wr, 2) uint32
    write_values: (N, B, Wr, VALUE_WORDS) uint32
    *_enabled:    optional masks (N, B, Rd/Wr) for ragged sets.
    fused:        True (default) runs the fused 3-4-round schedule;
                  False runs the per-phase 5-round reference.  Both produce
                  identical committed state, abort causes and delivered
                  request counts — the fused schedule just puts fewer
                  exchanges on the wire.
    nic:          optional repro.core.nic.ConnTable describing the connection
                  mode / emulated cluster scale; every round's WireStats then
                  carries the modeled NIC-cache hit rate and per-op
                  connection-state penalty (protocol results are unaffected).
    rep:          optional repro.core.replication.ReplicaConfig.  With f > 0,
                  COMMIT installs the write set on all f+1 copies — the f
                  backup writes ride the commit fused round as extra traffic
                  classes (zero additional exchange rounds; only the commit
                  round's (src, dst) fan-out widens).  rep=None and f=0 are
                  bit-identical to the unreplicated dataplane.
    ptable:       optional repro.core.placement.PlacementTable — ALL routing
                  (read probes, lock-class ops, commit backup fan-out) goes
                  through the epoch-stamped table instead of static
                  home/ring math.  Reads go to the first LIVE copy,
                  lock-class ops to the OWNER only; a stale table surfaces
                  as ``aborted_stale`` (owner-side ST_WRONG_EPOCH) for
                  txloop to refresh-and-retry.  The identity table with all
                  nodes up is bit-identical to ptable=None.

    Read/write sets are assumed disjoint per lane (read-for-update goes in the
    write set — its LOCK reply returns the current value, Fig. 3).
    """
    N, B, Rd = read_keys.shape[:3]
    Wr = write_keys.shape[2]
    if read_enabled is None:
        read_enabled = jnp.ones((N, B, Rd), bool)
    if write_enabled is None:
        write_enabled = jnp.ones((N, B, Wr), bool)

    if fused:
        return _run_transactions_fused(
            t, state, cfg, layout, read_keys=read_keys, write_keys=write_keys,
            write_values=write_values, write_enabled=write_enabled,
            read_enabled=read_enabled, cache=cache, use_onesided=use_onesided,
            capacity=capacity, nic=nic, rep=rep, ptable=ptable,
            telemetry=telemetry)

    serial_h = ht.make_rpc_handler(cfg, layout)

    # ---------------- EXECUTE: read set (hybrid one-two-sided) -------------
    state, cache, rctx = execute_read_set(
        t, state, cfg, layout, read_keys=read_keys, read_enabled=read_enabled,
        cache=cache, use_onesided=use_onesided, capacity=capacity, nic=nic,
        ptable=ptable, telemetry=telemetry)
    m = rctx["metrics"]

    # ---------------- EXECUTE: lock + read-for-update the write set --------
    state, lctx = lock_write_set(
        t, state, cfg, layout, serial_h, write_keys=write_keys,
        write_enabled=write_enabled, capacity=capacity, nic=nic,
        ptable=ptable, telemetry=telemetry)

    # ---------------- VALIDATE: one-sided re-read of read-set versions -----
    vctx = validate_read_set(t, state, layout, rctx, capacity=capacity,
                             nic=nic, telemetry=telemetry)

    state, res = _decide_and_finish(
        t, state, serial_h, N=N, B=B, Rd=Rd, Wr=Wr,
        write_enabled=write_enabled, write_values=write_values,
        rctx=rctx, lctx=lctx, vctx=vctx, read_wire=m.wire,
        onesided_success=m.onesided_success, rpc_fallback=m.rpc_fallback,
        total=m.total, capacity=capacity, nic=nic, rep=rep, ptable=ptable,
        telemetry=telemetry)
    return state, cache, res


# ===========================================================================
# Transactional RANGE SCANS over the ordered index (datastructs.btree).
#
# A scan transaction's READ SET is a run of B-link LEAVES: the client plans
# the (node, leaf) sequence covering [lo, hi] from its cached separator
# directory, reads each leaf with ONE one-sided read, and OCC-validates the
# leaf HEADER versions exactly like point transactions validate record slots
# (every record or structural change bumps the leaf version, so a validated
# scan is serializable at its validation point).  Writes lock whole leaves
# (OP_BT_LOCK pre-splits full leaves so OP_BT_COMMIT always has room).
#
# Two schedules, same phase records/handlers/decisions (mirroring
# run_transactions):
#
#   * fused=False — the 5-round reference: leaf reads, scan-RPC fallback,
#     LOCK, validate, COMMIT — one phase per all-to-all.
#   * fused=True (default) — the fallback rides the LOCK round and the
#     validate re-read of every leaf the one-sided read already resolved
#     rides it too (gathers observe the post-lock state):
#
#         round 1  one-sided reads of the planned leaves
#         round 2  scan fallback ∥ LOCK ∥ validate(one-sided-resolved)
#         round 3  validate(RPC-resolved leaves)   [empty on the fast path]
#         round 4  COMMIT / ABORT (+ OP_BT_BACKUP fan-out at rep.f > 0)
#
#     i.e. the fast-path scan costs EXACTLY the point-lookup schedule's
#     exchange rounds: 2 for a pure scan, 3 with writes — zero extra rounds
#     (asserted by benchmarks/range_scan.py and the bench gate).
#
# Stale separators (a leaf split since the last refresh) surface as a GAP in
# the fence chain: the lane aborts with cause `validate` and the retry loop
# (txloop.scan_loop) refreshes the directory — the round-trip analogue of
# chasing the B-link right-pointer.  `truncated` lanes (range needs more
# than cfg.max_scan_leaves leaves) are reported, parked, and never silently
# clipped.  Invariants mirror run_transactions: fused=True is
# round-count-only; rep=None ≡ rep.f == 0 bit-identical.
# ===========================================================================
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScanTxResult:
    committed: jnp.ndarray        # (N, B) bool
    scan_keys: jnp.ndarray        # (N, B, S, leaf_width) uint32
    scan_values: jnp.ndarray      # (N, B, S, leaf_width, VALUE_WORDS)
    scan_mask: jnp.ndarray        # (N, B, S, leaf_width) bool — in [lo, hi]
    scan_complete: jnp.ndarray    # (N, B) bool — fence chain covered [lo, hi]
    truncated: jnp.ndarray        # (N, B) bool — range needs > S leaves
    locked_values: jnp.ndarray    # (N, B, Wr, VALUE_WORDS)
    aborted_lock: jnp.ndarray     # (N, B) bool
    aborted_validate: jnp.ndarray
    aborted_overflow: jnp.ndarray
    aborted_stale: jnp.ndarray    # (N, B) bool — stale placement table
    metrics: hy.HybridMetrics
    round_trips: jnp.ndarray      # scalar


def _bt_lock_requests(t: Transport, cfg: bt.BTreeConfig, *, write_keys,
                      write_enabled, ptable=None):
    """Flatten the btree write set and build OP_BT_LOCK records (leaf-grain
    locks; unique nonzero tag per (node, lane) like the hash-table path).
    With a ``ptable``, lock-class ops route to the partition OWNER only
    (see _lock_requests — same dead-owner parking, same stale-epoch
    rejection owner-side)."""
    N, B, Wr = write_keys.shape
    wk = write_keys.reshape(N, B * Wr)
    en = write_enabled.reshape(N, B * Wr)
    part = bt.part_of(cfg, wk)
    wnode = part if ptable is None else pl.owner_dest(ptable, part)
    lane = jnp.arange(B * Wr, dtype=jnp.uint32) // jnp.uint32(max(Wr, 1))
    tag = (t.node_ids().astype(jnp.uint32)[:, None] * jnp.uint32(B)
           + lane[None, :] + jnp.uint32(1))
    recs = bt.make_record(W.OP_BT_LOCK, wk, jnp.zeros_like(wk), aux=tag)
    return dict(key_lo=wk, key_hi=jnp.zeros_like(wk), enabled=en, node=wnode,
                tag=tag, part=part), recs


def _bt_leaf_offset_of(layout, slot_idx):
    """Validation-offset hook: btree read-set entries are header slots in
    the `leaves` region."""
    return rg.slot_offset(layout["leaves"], slot_idx)


def _bt_commit_or_abort(t: Transport, state, serial_h, lock_ctx, *,
                        commit_lane, write_values,
                        capacity: Optional[int] = None, nic=None, rep=None,
                        ptable=None, telemetry=None):
    """COMMIT/ABORT for btree write sets.  Record layout: key in key_lo, the
    lock TAG in the (otherwise unused) key_hi word, the locked leaf's header
    slot in aux — the owner verifies the exact tag and installs the upsert
    (never splitting: OP_BT_LOCK pre-split, and the lock froze the leaf).

    With rep.f > 0, OP_BT_BACKUP classes ride this SAME fused round (zero
    extra exchange rounds — the PR-4 backup fan-out, logically replicated for
    the ordered index).  A backup write that is dropped, finds the backup
    leaf arena full (ST_NO_SPACE) or the backup leaf locked (ST_LOCK_FAIL)
    aborts its lane with cause overflow for the loop to retry — never a
    silent under-replication."""
    N, B = commit_lane.shape
    Wr = lock_ctx["key_lo"].shape[1] // max(B, 1)
    commit_item = jnp.repeat(commit_lane, Wr, axis=-1)
    op = jnp.where(commit_item, jnp.uint32(W.OP_BT_COMMIT),
                   jnp.uint32(W.OP_BT_ABORT))
    cm_recs = bt.make_record(
        op, lock_ctx["key_lo"], lock_ctx["tag"], aux=lock_ctx["lock_slot"],
        value=write_values.reshape(N, B * Wr, sl.VALUE_WORDS))
    classes = [rs.rpc_class(lock_ctx["node"], cm_recs, serial_h,
                            enabled=lock_ctx["lock_ok"], capacity=capacity)]
    bk_en = None
    if rep is not None and rep.f > 0:
        bk_recs = repl.btree_backup_records(lock_ctx, write_values)
        bk_en = commit_item & lock_ctx["lock_ok"]
        for i in range(1, rep.f + 1):
            classes.append(rs.rpc_class(
                _backup_dest(lock_ctx, rep, i, ptable), bk_recs, serial_h,
                enabled=bk_en, capacity=capacity))
    state, results, s_cm = rs.fused_round(t, state, classes, nic=nic,
                                          telemetry=telemetry,
                                          phase=T.PH_COMMIT)
    overflow = results[0][1] & lock_ctx["lock_ok"]
    for brep, bovf in results[1:]:
        bst = brep[..., 0]
        overflow = overflow | ((bovf | (bst == W.ST_NO_SPACE)
                                | (bst == W.ST_LOCK_FAIL)) & bk_en)
    return state, dict(overflow=overflow, wire=s_cm)


def _scan_chain(cfg: bt.BTreeConfig, fence_lo, fence_hi, lo, hi, en,
                resolved):
    """Client-side coverage check over the merged leaf run (all (N, B, S)).

    complete  — every enabled position resolved, fences contiguous
                (fence_lo[j] == fence_hi[j-1] + 1), the first leaf covers lo
                and some leaf reaches hi: the union of validated leaves IS
                [lo, hi] with no gap a concurrent split could hide a key in.
    truncated — the chain is sound but exhausts all S positions before
                reaching hi: the range genuinely needs > max_scan_leaves
                leaves (reported, never silently clipped)."""
    all_resolved = jnp.all(resolved | ~en, axis=-1)
    first_ok = fence_lo[..., 0] <= lo
    cont = fence_lo[..., 1:] == fence_hi[..., :-1] + 1
    cont_ok = jnp.all(cont | ~en[..., 1:], axis=-1)
    reach = jnp.any(en & (fence_hi >= hi[..., None]), axis=-1)
    has_scan = jnp.any(en, axis=-1)
    sound = all_resolved & first_ok & cont_ok
    complete = ~has_scan | (sound & reach)
    truncated = has_scan & en[..., -1] & sound & ~reach
    return complete, truncated


def run_scan_transactions(t: Transport, state, cfg: bt.BTreeConfig, layout, *,
                          scan_lo, scan_hi, meta, write_keys=None,
                          write_values=None, write_enabled=None,
                          scan_enabled=None, capacity: Optional[int] = None,
                          fused: bool = True, nic=None, rep=None,
                          ptable=None, telemetry=None):
    """Execute a batch of range-scan transactions over the ordered index,
    one per lane (single shot; see txloop.scan_loop for bounded retry).

    scan_lo/hi:   (N, B) uint32 INCLUSIVE key ranges (lo > hi scans nothing —
                  a pure-write lane).
    meta:         cached separator directory ({"sep", "nleaf"} from
                  btree.refresh_meta / local_meta) — the client-side inner
                  nodes every plan walks locally.
    write_keys:   (N, B, Wr) uint32 btree keys upserted on commit (None = no
                  writes); write_values (N, B, Wr, VALUE_WORDS).
    Limitations (btree module docstring): a lane's write keys must land on
    distinct leaves, and a lane must not write into leaves its own scan
    reads (leaf-grain self-conflict aborts forever).

    Returns (state, ScanTxResult).  fused/nic/rep/capacity as in
    run_transactions — fused changes ROUND COUNTS only, rep=None ≡ f=0.
    ptable routes the LOCK phase and commit backup fan-out through the
    placement table (scan reads stay a primary-tree protocol planned from
    ``meta``; stale routes abort ``aborted_stale`` for scan_loop to refresh
    both the table AND the separator directory)."""
    N, B = scan_lo.shape
    S = cfg.max_scan_leaves
    if write_keys is None:
        write_keys = jnp.zeros((N, B, 0), jnp.uint32)
        write_values = jnp.zeros((N, B, 0, sl.VALUE_WORDS), jnp.uint32)
    Wr = write_keys.shape[2]
    if write_enabled is None:
        write_enabled = jnp.ones((N, B, Wr), bool)
    if scan_enabled is None:
        scan_enabled = jnp.ones((N, B), bool)
    serial_h = bt.make_rpc_handler(cfg, layout)
    scan_h = bt.make_scan_handler_vector(cfg, layout)

    # client-side plan from the cached inner nodes (meta has a leading
    # client axis; each node plans its own lanes)
    plan = jax.vmap(
        lambda sep, nl, lo, hi: bt.scan_plan(cfg, sep, nl, lo, hi)
    )(meta["sep"], meta["nleaf"], scan_lo, scan_hi)
    en = plan["enabled"] & scan_enabled[..., None]              # (N, B, S)
    en_f = en.reshape(N, B * S)
    dest = plan["node"].reshape(N, B * S)
    pleaf = plan["leaf"].reshape(N, B * S)
    pfence = plan["fence"].reshape(N, B * S)

    # ---- round 1: one-sided reads of the planned leaves -------------------
    buf, ovf1, s1 = osd.remote_read(
        t, state["arena"], dest, bt.leaf_offset(cfg, layout, pleaf),
        length=cfg.leaf_words, capacity=capacity, enabled=en_f, nic=nic,
        telemetry=telemetry, phase=T.PH_READ)
    p1 = bt.parse_leaf(cfg, buf)
    # a position is resolved one-sided iff the image is stable and its
    # immutable low fence matches the plan (stale separators can only MISS
    # leaves, never mis-assign fences)
    pos_ok = (en_f & ~ovf1 & (p1["version"] % 2 == 0) & (p1["lock"] == 0)
              & (p1["fence_lo"] == pfence))
    need = en_f & ~pos_ok
    scan_recs = bt.make_record(W.OP_BT_SCAN, pfence, jnp.zeros_like(pfence))
    lk, lock_recs = _bt_lock_requests(t, cfg, write_keys=write_keys,
                                      write_enabled=write_enabled,
                                      ptable=ptable)

    fuse_v1 = fused and capacity is None and S > 0
    if fused:
        # ---- round 2: scan fallback ∥ LOCK ∥ validate(one-sided-resolved) -
        classes = [
            rs.rpc_class(dest, scan_recs, scan_h, enabled=need,
                         capacity=capacity),
            rs.rpc_class(lk["node"], lock_recs, serial_h,
                         enabled=lk["enabled"], capacity=capacity),
        ]
        if fuse_v1:
            classes.append(rs.read_class(
                dest, _bt_leaf_offset_of(layout, bt.header_slot(cfg, pleaf)),
                length=sl.SLOT_WORDS, enabled=pos_ok))
        state, results, s2 = rs.fused_round(t, state, classes, nic=nic,
                                            telemetry=telemetry,
                                            phase=T.PH_LOCK)
        scan_rep, scan_ovf = results[0]
        lrep, lovf = results[1]
        s_fallback = None
    else:
        # ---- reference rounds 2 and 3: fallback, then LOCK ----------------
        state, scan_rep, scan_ovf, s_fallback = R.rpc_call(
            t, state, dest, scan_recs, scan_h, capacity=capacity,
            enabled=need, nic=nic, telemetry=telemetry, phase=T.PH_FALLBACK)
        state, lrep, lovf, s2 = R.rpc_call(
            t, state, lk["node"], lock_recs, serial_h, capacity=capacity,
            enabled=lk["enabled"], nic=nic, telemetry=telemetry,
            phase=T.PH_LOCK)
    lctx = _parse_lock_replies(lk, lrep, lovf, N, B, Wr)

    # merge the authoritative fallback leaf images over the one-sided reads
    rpc_ok = need & (scan_rep[..., 0] == W.ST_OK) & ~scan_ovf
    mbuf = jnp.where(rpc_ok[..., None], scan_rep[..., 2:], buf)
    mslot = jnp.where(rpc_ok, scan_rep[..., 1], bt.header_slot(cfg, pleaf))
    p = bt.parse_leaf(cfg, mbuf)
    resolved = pos_ok | rpc_ok
    rctx = dict(key_lo=p["fence_lo"], key_hi=jnp.zeros_like(p["fence_lo"]),
                enabled=en_f, found=resolved, versions=p["version"],
                node=dest, slot=mslot, overflow=need & scan_ovf)

    # ---- validate the leaf read set (headers) -----------------------------
    if fuse_v1:
        v1 = results[2][0]
        v2, _, s3 = osd.remote_read(
            t, state["arena"], dest, _bt_leaf_offset_of(layout, mslot),
            length=sl.SLOT_WORDS, enabled=rpc_ok, nic=nic,
            telemetry=telemetry, phase=T.PH_VALIDATE)
        vbuf = jnp.where(pos_ok[..., None], v1, v2)
        vctx = _validate_from_bytes(rctx, vbuf, jnp.zeros((N, B * S), bool))
        vctx["wire"] = s3
    else:
        vctx = validate_read_set(t, state, layout, rctx, capacity=capacity,
                                 nic=nic, offset_of=_bt_leaf_offset_of,
                                 telemetry=telemetry)
    read_wire = s1 if s_fallback is None else s1 + s_fallback
    lctx["wire"] = s2

    # ---- decide, commit / abort, classify ---------------------------------
    complete, truncated = _scan_chain(
        cfg, p["fence_lo"].reshape(N, B, S), p["fence_hi"].reshape(N, B, S),
        scan_lo, scan_hi, en, resolved.reshape(N, B, S))
    lane_locks_ok = jnp.all(
        (lctx["lock_ok"] | ~lctx["enabled"]).reshape(N, B, Wr), axis=-1)
    lane_valid = jnp.all(
        (vctx["valid"] | ~en_f).reshape(N, B, S), axis=-1) & complete
    lane_reads_ok = ~jnp.any(
        (rctx["overflow"] | vctx["overflow"]).reshape(N, B, S), axis=-1)

    commit_lane = lane_locks_ok & lane_valid & lane_reads_ok
    state, cctx = _bt_commit_or_abort(
        t, state, serial_h, lctx, commit_lane=commit_lane,
        write_values=write_values, capacity=capacity, nic=nic, rep=rep,
        ptable=ptable, telemetry=telemetry)

    has_writes = jnp.any(write_enabled, axis=-1)
    commit_delivered = ~jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1)
    committed = jnp.where(has_writes, commit_lane & commit_delivered,
                          lane_valid & lane_reads_ok)

    lane_ovf = (~lane_reads_ok
                | jnp.any(lctx["no_space"].reshape(N, B, Wr), axis=-1)
                | jnp.any(cctx["overflow"].reshape(N, B, Wr), axis=-1))
    lane_stale = jnp.any(lctx["stale"].reshape(N, B, Wr), axis=-1)
    lane_lock_fail = jnp.any(lctx["lock_fail"].reshape(N, B, Wr), axis=-1)
    aborted = ~committed
    aborted_overflow = aborted & lane_ovf
    aborted_stale = aborted & ~lane_ovf & lane_stale
    aborted_lock = aborted & ~lane_ovf & ~lane_stale & lane_lock_fail
    aborted_validate = (aborted & ~lane_ovf & ~lane_stale & ~lane_lock_fail
                        & ~lane_valid)

    # ---- scan payload: records of validated leaves inside [lo, hi] --------
    keys = p["keys"].reshape(N, B, S, cfg.leaf_width)
    values = p["values"].reshape(N, B, S, cfg.leaf_width, sl.VALUE_WORDS)
    live = p["live"].reshape(N, B, S, cfg.leaf_width)
    in_range = (live & (keys >= scan_lo[..., None, None])
                & (keys <= scan_hi[..., None, None])
                & (resolved.reshape(N, B, S) & en)[..., None])

    wire = read_wire + lctx["wire"] + vctx["wire"] + cctx["wire"]
    metrics = hy.HybridMetrics(
        onesided_success=jnp.sum(pos_ok.astype(jnp.float32)),
        rpc_fallback=jnp.sum(need.astype(jnp.float32)),
        total=jnp.sum(en_f.astype(jnp.float32)),
        wire=wire)
    rts = (read_wire.round_trips + lctx["wire"].round_trips
           + vctx["wire"].round_trips + cctx["wire"].round_trips)
    return state, ScanTxResult(
        committed=committed,
        scan_keys=keys, scan_values=values, scan_mask=in_range,
        scan_complete=complete, truncated=truncated,
        locked_values=lctx["locked_values"],
        aborted_lock=aborted_lock, aborted_validate=aborted_validate,
        aborted_overflow=aborted_overflow, aborted_stale=aborted_stale,
        metrics=metrics, round_trips=rts)
