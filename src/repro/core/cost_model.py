"""Napkin-math cost model behind every hybrid one-sided-vs-RPC decision
(Storm §4.4/§4.5 lifted to a reusable selector).

The decision is always the same shape: move DATA to the requester (one-sided
read) or move the REQUEST to the data and compute there (RPC).  We compare
bytes over the interconnect per logical operation, plus a round-trip term.
The same model prices the framework's three integration points:

  * KV-cache decode attention: gather K/V rows vs ship Q + partial results
  * MoE dispatch: all-gather expert weights vs all-to-all token activations
  * vocab-sharded embedding: gather rows vs ship ids

Trace-time decisions only (static shapes -> static schedule, the TPU
analogue of Storm's "connections give you a hardware-managed data path").
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Per-chip link characteristics (TPU v5e-class defaults)."""
    link_bytes_per_s: float = 50e9     # ICI per link
    hbm_bytes_per_s: float = 819e9
    flops_per_s: float = 197e12        # bf16
    rt_overhead_s: float = 1e-6        # per collective round fixed cost
    # modeled per-op connection-state penalty (core.nic: NIC-cache misses +
    # QP-sharing locks / DC reconnects).  0 = perfect NIC; use with_nic() to
    # derive a Fabric priced for a concrete connection mode / cluster scale.
    nic_penalty_s: float = 0.0

    def with_nic(self, conn_table) -> "Fabric":
        """A copy of this fabric paying `conn_table`'s per-op penalty
        (conn_table: repro.core.nic.ConnTable)."""
        return dataclasses.replace(
            self, nic_penalty_s=conn_table.penalty_us_per_op * 1e-6)


@dataclasses.dataclass(frozen=True)
class Choice:
    mode: str                 # "onesided" | "rpc"
    onesided_bytes: float
    rpc_bytes: float
    onesided_time: float
    rpc_time: float

    @property
    def ratio(self) -> float:
        return self.onesided_time / max(self.rpc_time, 1e-30)


def choose(onesided_bytes: float, rpc_bytes: float,
           onesided_rounds: float = 1.0, rpc_rounds: float = 1.0,
           fabric: Fabric = Fabric(), rpc_compute_flops: float = 0.0) -> Choice:
    """Pick the cheaper primitive for one logical op (bytes on the wire +
    round-trip overhead + any owner-side compute the RPC must run).  Both
    sides pay the fabric's modeled connection-state penalty once per round
    issued (every round touches the connection's QP/DC state)."""
    t1 = (onesided_bytes / fabric.link_bytes_per_s
          + onesided_rounds * (fabric.rt_overhead_s + fabric.nic_penalty_s))
    t2 = (rpc_bytes / fabric.link_bytes_per_s
          + rpc_rounds * (fabric.rt_overhead_s + fabric.nic_penalty_s)
          + rpc_compute_flops / fabric.flops_per_s)
    mode = "onesided" if t1 <= t2 else "rpc"
    return Choice(mode, onesided_bytes, rpc_bytes, t1, t2)


# ---------------------------------------------------------------------------
# Framework integration points
# ---------------------------------------------------------------------------
def decode_attention_choice(*, seq_len: int, n_kv_heads: int, n_q_heads: int,
                            head_dim: int, batch_per_shard: int, shards: int,
                            bytes_per_el: int = 2,
                            fabric: Fabric = Fabric()) -> Choice:
    """One decode step, KV sharded `shards`-ways along sequence.

    one-sided: gather the remote KV rows to the query's shard:
               2 (K and V) * S * (shards-1)/shards * n_kv * hd bytes / query
    rpc:       broadcast Q to the shards and return (o, m, l) partials:
               (shards-1) * (n_q*hd [q] + n_q*(hd+2) [partials]) bytes.
    """
    b = batch_per_shard
    one = 2 * seq_len * ((shards - 1) / shards) * n_kv_heads * head_dim * bytes_per_el * b
    rpc = (shards - 1) * (n_q_heads * head_dim + n_q_heads * (head_dim + 2)) * bytes_per_el * b
    # owner-side compute the RPC runs: 4*S/shards*n_q*hd flops per shard chain
    flops = 4 * (seq_len / shards) * n_q_heads * head_dim * b
    return choose(one, rpc, fabric=fabric, rpc_compute_flops=flops)


def moe_dispatch_choice(*, tokens_per_shard: int, d_model: int, d_ff: int,
                        n_experts: int, top_k: int, shards: int,
                        bytes_per_el: int = 2,
                        fabric: Fabric = Fabric()) -> Choice:
    """Prices the two IMPLEMENTED schedules (models.moe):
    one-sided: all-gather expert weights ((s-1)/s remote) + all-gather the
               1/s-split outputs back — perfectly balanced compute;
    rpc:       local-expert partials + ring all-reduce of (tokens, d)
               (2 (s-1)/s x bytes) — compute lands where the experts live."""
    f = (shards - 1) / shards
    act = tokens_per_shard * d_model * bytes_per_el
    weights = n_experts * 3 * d_model * d_ff * bytes_per_el
    one = f * (weights + act)
    rpc = 2 * f * act
    flops = 6 * tokens_per_shard * top_k * d_model * d_ff / shards
    return choose(one, rpc, fabric=fabric, rpc_compute_flops=flops)


def embedding_lookup_choice(*, tokens_per_shard: int, d_model: int,
                            vocab: int, shards: int, bytes_per_el: int = 2,
                            fabric: Fabric = Fabric()) -> Choice:
    """one-sided: all-gather the vocab-sharded table, take rows locally;
    rpc: every shard contributes its rows, ring all-reduce of (tokens, d)
    (the masked-psum handler in models.embedding)."""
    f = (shards - 1) / shards
    one = f * vocab * d_model * bytes_per_el
    rpc = 2 * f * tokens_per_shard * d_model * bytes_per_el
    return choose(one, rpc, fabric=fabric)
