"""Equivalence suite: the fused 3-4-round schedule must produce IDENTICAL
committed state, abort causes, read results, and delivered-request counts
(WireStats.ops) as the per-phase 5-round reference — across the property-test
workloads, under capacity back-pressure, and through max_rounds > 1 retries.
The only things allowed to differ are round_trips/messages/bytes (that is the
whole point of fusing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rpc as R
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.core.txloop import tx_loop
from repro.testing.workloads import value_for, zipf_write_keys

N = 2


@pytest.fixture(scope="module")
def cfg():
    return ht.HashTableConfig(n_nodes=N, n_buckets=16, bucket_width=2,
                              n_overflow=32, max_chain=10)


@pytest.fixture(scope="module")
def layout(cfg):
    return ht.build_layout(cfg)


def insert_keys(t, state, cfg, layout, klo, khi):
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi,
                                       value=value_for(klo)), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    return state


RESULT_FIELDS = ("committed", "read_found", "read_values", "locked_values",
                 "aborted_lock", "aborted_validate", "aborted_overflow")


def assert_equivalent(t, state, cfg, layout, rk, wk, wv, **kw):
    """Run both schedules from the same state and compare everything the
    satellite demands; returns (ref, fused) results for extra assertions."""
    s_ref, _, ref = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=False, **kw)
    s_fus, _, fus = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=True, **kw)
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(fus, f)),
            err_msg=f"fused/reference mismatch in {f}")
    np.testing.assert_array_equal(np.asarray(s_ref["arena"]),
                                  np.asarray(s_fus["arena"]),
                                  err_msg="committed state differs")
    assert float(ref.metrics.wire.ops) == float(fus.metrics.wire.ops), \
        "delivered-request counts must match"
    # the fused schedule must actually save exchanges whenever the reference
    # issued the full 5 (read / fallback / lock / validate / commit)
    assert float(fus.round_trips) <= float(ref.round_trips)
    return ref, fus


def make_tx_workload(seed, B=4, Rd=2, Wr=1):
    rng = np.random.RandomState(seed)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + Wr)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + Wr)), jnp.uint32)
    rk = jnp.stack([klo[..., :Rd], khi[..., :Rd]], -1)
    wk = jnp.stack([klo[..., Rd:], khi[..., Rd:]], -1)
    wv = value_for(klo[..., Rd:] + jnp.uint32(9))
    return klo, khi, rk, wk, wv


def test_disjoint_commit_equivalence(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_tx_workload(seed=0)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    ref, fus = assert_equivalent(t, state, cfg, layout, rk, wk, wv)
    assert bool(np.asarray(ref.committed).all())
    # reference = 5 rounds (read + fallback + lock + validate + commit, the
    # fallback only live if some read chained); fused = 4 general / 3 when
    # every read-set lookup was satisfied one-sided
    assert float(ref.round_trips) in (4.0, 5.0)
    assert float(fus.round_trips) == float(ref.round_trips) - 1.0


def test_fast_path_is_three_rounds(cfg, layout):
    """All read-set lookups satisfied one-sided -> exactly 3 exchange rounds
    (read ∥ lock+validate ∥ commit)."""
    big = ht.HashTableConfig(n_nodes=N, n_buckets=256, bucket_width=1,
                             n_overflow=8, max_chain=4)
    big_layout = ht.build_layout(big)
    t = SimTransport(N)
    state = ht.init_cluster_state(big)
    klo, khi, rk, wk, wv = make_tx_workload(seed=1)
    state = insert_keys(t, state, big, big_layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    _, _, fus = txm.run_transactions(
        t, state, big, big_layout, read_keys=rk, write_keys=wk,
        write_values=wv, fused=True)
    m = fus.metrics
    if float(m.rpc_fallback) == 0.0:
        assert float(fus.round_trips) == 3.0
    else:  # an unlucky chain: still within the general-case bound
        assert float(fus.round_trips) == 4.0
    assert bool(np.asarray(fus.committed).all())


def test_contended_key_equivalence(cfg, layout):
    """Every lane writes the SAME key: the fused lock round must elect the
    same single winner as the reference (scan order preserved)."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 4
    key = jnp.full((N, B, 1), 4242, jnp.uint32)
    khi = jnp.zeros_like(key)
    state = insert_keys(t, state, cfg, layout,
                        key.reshape(N, -1), khi.reshape(N, -1))
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([key, khi], -1)
    wv = value_for(key + jnp.uint32(5))
    ref, fus = assert_equivalent(t, state, cfg, layout, rk, wk, wv)
    assert int(np.asarray(ref.committed).sum()) == 1
    assert int(np.asarray(ref.aborted_lock).sum()) == N * B - 1


def test_backpressure_equivalence(cfg, layout):
    """Tiny per-destination capacity: identical overflow aborts, identical
    delivered counts, identical committed state."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_tx_workload(seed=2, B=6)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    for cap in (1, 2):
        ref, fus = assert_equivalent(t, state, cfg, layout, rk, wk, wv,
                                     capacity=cap)
    # capacity=1 must actually produce overflow aborts for this shape,
    # otherwise the test is vacuous
    _, _, ref1 = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=False, capacity=1)
    assert int(np.asarray(ref1.aborted_overflow).sum()) > 0


def test_rpc_only_mode_equivalence(cfg, layout):
    """use_onesided=False: every read goes through the fused fallback+lock
    round; the reference needs separate rounds for each."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_tx_workload(seed=3)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    ref, fus = assert_equivalent(t, state, cfg, layout, rk, wk, wv,
                                 use_onesided=False)
    # reference: fallback + lock + validate + commit; fused: fallback∥lock,
    # validate, commit
    assert float(ref.round_trips) == 4.0
    assert float(fus.round_trips) == 3.0


def test_txloop_retry_equivalence(cfg, layout):
    """Bounded retry under skewed contention + back-pressure: the whole loop
    (same PRNG, same permutations) must converge identically."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 6
    hot, klo, khi = zipf_write_keys(N, B, seed=4)
    state = insert_keys(t, state, cfg, layout, jnp.tile(hot[None], (N, 1)),
                        jnp.zeros((N, hot.shape[0]), jnp.uint32))
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo + jnp.uint32(5))
    s_ref, _, ref = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                            write_values=wv, capacity=2, max_rounds=4,
                            fused=False)
    s_fus, _, fus = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                            write_values=wv, capacity=2, max_rounds=4,
                            fused=True)
    np.testing.assert_array_equal(np.asarray(ref.committed),
                                  np.asarray(fus.committed))
    np.testing.assert_array_equal(np.asarray(ref.commit_round),
                                  np.asarray(fus.commit_round))
    for f in ("round_committed", "round_attempts", "round_abort_lock",
              "round_abort_validate", "round_abort_overflow"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(fus, f)),
                                      err_msg=f"loop metric mismatch: {f}")
    np.testing.assert_array_equal(np.asarray(s_ref["arena"]),
                                  np.asarray(s_fus["arena"]))
    assert float(ref.metrics.wire.ops) == float(fus.metrics.wire.ops)
    # write-only lanes need only lock + commit on both schedules, so the
    # fused loop matches (and never exceeds) the reference here
    assert float(fus.round_trips) <= float(ref.round_trips)


def test_address_cache_equivalence():
    """With the client address cache on, both schedules must learn the same
    cache entries and agree on a warm second batch."""
    cfgc = ht.HashTableConfig(n_nodes=N, n_buckets=4, bucket_width=1,
                              n_overflow=32, max_chain=20, cache_slots=128)
    layoutc = ht.build_layout(cfgc)
    t = SimTransport(N)
    state = ht.init_cluster_state(cfgc)
    klo, khi, rk, wk, wv = make_tx_workload(seed=5)
    state = insert_keys(t, state, cfgc, layoutc,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    cache0 = jax.tree.map(lambda x: jnp.tile(x[None], (N,) + (1,) * x.ndim),
                          ht.init_cache(cfgc))
    _, cache_ref, ref = txm.run_transactions(
        t, state, cfgc, layoutc, read_keys=rk, write_keys=wk, write_values=wv,
        cache=cache0, fused=False)
    _, cache_fus, fus = txm.run_transactions(
        t, state, cfgc, layoutc, read_keys=rk, write_keys=wk, write_values=wv,
        cache=cache0, fused=True)
    np.testing.assert_array_equal(np.asarray(ref.committed),
                                  np.asarray(fus.committed))
    for k in cache_ref:
        np.testing.assert_array_equal(np.asarray(cache_ref[k]),
                                      np.asarray(cache_fus[k]),
                                      err_msg=f"cache field mismatch: {k}")
