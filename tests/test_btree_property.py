"""Property suite: the B-link tree against a sorted-dict reference model
under random insert/delete/scan churn (hypothesis, or the deterministic
fixed-sample stub where hypothesis is absent).

Every batch of mutations goes through the real RPC dataplane; after each
batch the tree must agree with the model on point lookups (present AND
absent keys), ordered range scans (via the real scan-transaction machinery
with freshly refreshed separators), and the structural walk invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import rpc as R
from repro.core import tx as txm
from repro.core import wireproto as W
from repro.core.datastructs import btree as bt
from repro.core.transport import SimTransport
from repro.testing.workloads import value_for

N = 2          # small cluster: the property loop re-jits nothing per example
BATCH = 8
ROUNDS = 3


@pytest.fixture(scope="module")
def cfg():
    return bt.BTreeConfig(n_nodes=N, n_leaves=24, leaf_width=4,
                          max_scan_leaves=6)


@pytest.fixture(scope="module")
def layout(cfg):
    return bt.build_layout(cfg)


def apply_batch(t, state, cfg, layout, ops, keys):
    """ops/keys: (N, BATCH) numpy; op 0 = insert, 1 = delete."""
    h = bt.make_rpc_handler(cfg, layout)
    op = jnp.where(jnp.asarray(ops) == 0, jnp.uint32(W.OP_BT_INSERT),
                   jnp.uint32(W.OP_BT_DELETE))
    k = jnp.asarray(keys, jnp.uint32)
    state, rep, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, k),
        bt.make_record(op, k, jnp.zeros_like(k), value=value_for(k)), h)
    return state, np.asarray(rep[..., 0])


def model_apply(model, ops, keys):
    """The sorted-dict reference: inserts upsert, deletes drop.  The handler
    serializes each node's inbox source-major (transport exchange order), so
    replay column-by-column — but keys here are drawn per-column distinct,
    making the batch order-insensitive anyway."""
    for s in range(ops.shape[0]):
        for c in range(ops.shape[1]):
            k = int(keys[s, c])
            if ops[s, c] == 0:
                model[k] = True
            else:
                model.pop(k, None)


def test_btree_against_sorted_dict_reference(cfg, layout):
    """Deterministic churn sweep (always runs, wider than the @given one)."""
    _churn(cfg, layout, seed=1234, key_space=2**14)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), log_space=st.sampled_from([10, 16, 28]))
def test_btree_against_sorted_dict_reference_random(cfg, layout, seed,
                                                    log_space):
    _churn(cfg, layout, seed=seed, key_space=2 ** log_space)


def _draw_keys(rng, model, key_space, n):
    """n DISTINCT keys: roughly a third re-drawn from the model's live keys
    (so deletes and update-inserts actually hit), the rest fresh."""
    live = sorted(model)
    chosen, seen = [], set()
    want_live = min(len(live), n // 3)
    for k in rng.permutation(live)[:want_live]:
        chosen.append(int(k))
        seen.add(int(k))
    while len(chosen) < n:
        k = int(rng.randint(0, key_space))
        if k not in seen:
            chosen.append(k)
            seen.add(k)
    return np.asarray(rng.permutation(chosen), np.int64)


def _churn(cfg, layout, *, seed, key_space):
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(seed)
    model = {}
    committed_scans = 0
    for _ in range(ROUNDS):
        ops = rng.randint(0, 2, (N, BATCH))
        keys = _draw_keys(rng, model, key_space, N * BATCH).reshape(N, BATCH)
        state, status = apply_batch(t, state, cfg, layout, ops, keys)
        assert ((status == W.ST_OK) | (status == W.ST_NOT_FOUND)).all(), \
            "churn at this occupancy must never exhaust leaves or lock-fail"
        model_apply(model, ops, keys)

        # --- point agreement: present and absent keys --------------------
        h = bt.make_rpc_handler(cfg, layout)
        probe = jnp.asarray(keys, jnp.uint32)
        _, rep, _, _ = R.rpc_call(
            t, state, bt.home_of(cfg, probe),
            bt.make_record(W.OP_BT_LOOKUP, probe, jnp.zeros_like(probe)), h)
        st_ = np.asarray(rep[..., 0]).reshape(-1)
        exp = np.asarray([int(k) in model for k in keys.reshape(-1)])
        np.testing.assert_array_equal(st_ == W.ST_OK, exp)

        # --- ordered agreement: scans against the sorted model -----------
        meta = bt.local_meta(cfg, layout, state)
        live = sorted(model)
        if len(live) < 2:
            continue
        pick = rng.randint(0, len(live) - 1, (N, 2))
        hi_i = np.minimum(pick + 3, len(live) - 1)
        lo = jnp.asarray(np.asarray(live)[pick], jnp.uint32)
        hi = jnp.asarray(np.asarray(live)[hi_i], jnp.uint32)
        _, res = txm.run_scan_transactions(t, state, cfg, layout, scan_lo=lo,
                                           scan_hi=hi, meta=meta)
        com = np.asarray(res.committed)
        trunc = np.asarray(res.truncated)
        # fragmentation (deletes leave sparse leaves) may legally truncate a
        # range past max_scan_leaves — but it must be REPORTED, never a
        # silently clipped "success"
        assert (com | trunc).all(), "fresh-meta scans must commit or report"
        sk, sm = np.asarray(res.scan_keys), np.asarray(res.scan_mask)
        for n in range(N):
            for b in range(2):
                if not com[n, b]:
                    continue
                committed_scans += 1
                got = sorted(sk[n, b][sm[n, b]].tolist())
                want = [k for k in live if int(np.asarray(lo)[n, b]) <= k
                        <= int(np.asarray(hi)[n, b])]
                assert got == want, (seed, n, b, got, want)
    assert committed_scans > 0, "vacuous run: every scan truncated"
