"""Transactional range scans over the ordered index: fused ≡ unfused and
rep=None ≡ f=0 bit-identity, the zero-extra-rounds claim (fast-path scan ==
point-lookup schedule), OCC conflict aborts + scan_loop convergence,
truncation reporting, and f=1 logical replication."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import replication as repl
from repro.core import rpc as R
from repro.core import tx as txm
from repro.core import txloop as txl
from repro.core import wireproto as W
from repro.core.datastructs import btree as bt
from repro.core.transport import SimTransport
from repro.testing.workloads import distinct_uint32, value_for

N = 4
B = 4

WIRE_FIELDS = ("round_trips", "messages", "ops", "req_bytes", "reply_bytes",
               "nic_hit_ops", "nic_penalty_us")
RESULT_FIELDS = ("committed", "scan_keys", "scan_values", "scan_mask",
                 "scan_complete", "truncated", "locked_values",
                 "aborted_lock", "aborted_validate", "aborted_overflow")


@pytest.fixture(scope="module")
def cfg():
    return bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4,
                          max_scan_leaves=4)


@pytest.fixture(scope="module")
def layout(cfg):
    return bt.build_layout(cfg)


def insert(t, state, cfg, layout, keys):
    h = bt.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, keys),
        bt.make_record(W.OP_BT_INSERT, keys, jnp.zeros_like(keys),
                       value=value_for(keys)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    return state


@pytest.fixture(scope="module")
def populated(cfg, layout):
    """A populated tree + fresh meta + deterministic scan ranges that each
    span a handful of keys (and sometimes a node boundary)."""
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(17)
    allk = np.sort(distinct_uint32(rng, N * 12).astype(np.uint64))
    keys = jnp.asarray(allk.reshape(N, 12), jnp.uint32)
    state = insert(t, state, cfg, layout, keys)
    meta = bt.local_meta(cfg, layout, state)
    # each lane scans from a chosen key to 5 keys later (inclusive)
    starts = rng.choice(len(allk) - 6, N * B, replace=False)
    lo = jnp.asarray(allk[starts].reshape(N, B), jnp.uint32)
    hi = jnp.asarray(allk[starts + 5].reshape(N, B), jnp.uint32)
    return t, state, meta, allk, lo, hi


def expected_range(allk, lo, hi):
    return sorted(int(k) for k in allk if lo <= k <= hi)


def mixed_workload(allk, lo, hi, seed=29):
    """Half the lanes scan, half upsert a fresh GAP key (a lane must not
    write into leaves its own scan reads — the documented leaf-grain
    self-conflict rule; cross-LANE conflicts are exactly what OCC handles)."""
    rng = np.random.RandomState(seed)
    is_scan = np.arange(B) % 2 == 0
    slo = jnp.asarray(np.where(is_scan[None], np.asarray(lo), 1), jnp.uint32)
    shi = jnp.asarray(np.where(is_scan[None], np.asarray(hi), 0), jnp.uint32)
    g = rng.randint(0, len(allk) - 1, (N, B))
    wkn = allk[g] + np.maximum((allk[g + 1] - allk[g]) // 2, 1)
    assert len(np.intersect1d(wkn.ravel(), allk)) == 0, "gap keys not fresh"
    wk = jnp.asarray(wkn, jnp.uint32)[..., None]
    wen = jnp.asarray(np.broadcast_to((~is_scan)[None, :, None], (N, B, 1)))
    return slo, shi, wk, wen


def scanned(res, n, b):
    sk, sm = np.asarray(res.scan_keys), np.asarray(res.scan_mask)
    return sorted(sk[n, b][sm[n, b]].tolist())


def test_pure_scan_matches_reference_and_costs_point_rounds(cfg, layout,
                                                            populated):
    t, state, meta, allk, lo, hi = populated
    _, res = txm.run_scan_transactions(t, state, cfg, layout, scan_lo=lo,
                                       scan_hi=hi, meta=meta)
    assert bool(np.asarray(res.committed).all())
    assert bool(np.asarray(res.scan_complete).all())
    assert not bool(np.asarray(res.truncated).any())
    for n in range(N):
        for b in range(B):
            assert scanned(res, n, b) == expected_range(
                allk, int(np.asarray(lo)[n, b]), int(np.asarray(hi)[n, b]))
    # values travel with the records
    sv, sm = np.asarray(res.scan_values), np.asarray(res.scan_mask)
    exp = np.asarray(value_for(res.scan_keys))
    np.testing.assert_array_equal(sv[sm], exp[sm])
    # fresh meta => every leaf read resolved one-sided, and the scan costs
    # EXACTLY the point-lookup schedule's exchange rounds: read + fused
    # (validate) round = 2, zero extra
    assert float(res.metrics.rpc_fallback) == 0.0
    assert float(res.round_trips) == 2.0


def test_fused_unfused_bit_identical(cfg, layout, populated):
    t, state, meta, allk, lo, hi = populated
    slo, shi, wk, wen = mixed_workload(allk, lo, hi)
    wv = value_for(wk)
    for kwargs in (dict(scan_lo=lo, scan_hi=hi),
                   dict(scan_lo=slo, scan_hi=shi, write_keys=wk,
                        write_values=wv, write_enabled=wen)):
        s_ref, r_ref = txm.run_scan_transactions(
            t, state, cfg, layout, meta=meta, fused=False, **kwargs)
        s_fus, r_fus = txm.run_scan_transactions(
            t, state, cfg, layout, meta=meta, fused=True, **kwargs)
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(r_ref, f)), np.asarray(getattr(r_fus, f)),
                err_msg=f"fused changed {f}")
        np.testing.assert_array_equal(np.asarray(s_ref["arena"]),
                                      np.asarray(s_fus["arena"]),
                                      err_msg="fused changed committed state")
        assert float(r_ref.metrics.wire.ops) == float(r_fus.metrics.wire.ops)
        assert float(r_fus.round_trips) <= float(r_ref.round_trips)


def test_rep_none_equals_f0(cfg, layout, populated):
    t, state, meta, allk, lo, hi = populated
    slo, shi, wk, wen = mixed_workload(allk, lo, hi)
    wv = value_for(wk)
    for fused in (False, True):
        s_a, r_a = txm.run_scan_transactions(
            t, state, cfg, layout, scan_lo=slo, scan_hi=shi, meta=meta,
            write_keys=wk, write_values=wv, write_enabled=wen, fused=fused,
            rep=None)
        s_b, r_b = txm.run_scan_transactions(
            t, state, cfg, layout, scan_lo=slo, scan_hi=shi, meta=meta,
            write_keys=wk, write_values=wv, write_enabled=wen, fused=fused,
            rep=repl.ReplicaConfig(N, 0))
        for f in RESULT_FIELDS + ("round_trips",):
            np.testing.assert_array_equal(np.asarray(getattr(r_a, f)),
                                          np.asarray(getattr(r_b, f)),
                                          err_msg=f"f=0 changed {f}")
        for f in WIRE_FIELDS:
            assert float(getattr(r_a.metrics.wire, f)) == \
                float(getattr(r_b.metrics.wire, f)), f
        np.testing.assert_array_equal(np.asarray(s_a["arena"]),
                                      np.asarray(s_b["arena"]))


def test_f1_zero_extra_rounds_and_logical_copies(cfg, layout, populated):
    t, state, meta, allk, lo, hi = populated
    slo, shi, wk, wen = mixed_workload(allk, lo, hi)
    wv = value_for(wk)
    _, r0 = txm.run_scan_transactions(
        t, state, cfg, layout, scan_lo=slo, scan_hi=shi, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen)
    rc = repl.ReplicaConfig(N, 1)
    s1, r1 = txm.run_scan_transactions(
        t, state, cfg, layout, scan_lo=slo, scan_hi=shi, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen, rep=rc)
    assert float(r1.round_trips) == float(r0.round_trips), \
        "backup classes must ride the commit round (zero extra rounds)"
    np.testing.assert_array_equal(np.asarray(r1.committed),
                                  np.asarray(r0.committed))
    # every committed WRITE lane's key is served, with the SAME value, by
    # both the primary and its backup (logical replication)
    h = bt.make_rpc_handler(cfg, layout)
    com_w = np.asarray(r1.committed) & np.asarray(wen)[..., 0]
    assert com_w.any(), "vacuous: no write lane committed"
    wkf = wk.reshape(N, B)
    pn = bt.home_of(cfg, wkf)
    for dest in (pn, rc.replica_of(pn, 1)):
        _, rep, _, _ = R.rpc_call(
            t, s1, dest, bt.make_record(W.OP_BT_LOOKUP, wkf,
                                        jnp.zeros_like(wkf)), h)
        st = np.asarray(rep[..., 0])
        vals = np.asarray(rep[..., 3:])
        assert (st[com_w] == W.ST_OK).all()
        np.testing.assert_array_equal(vals[com_w],
                                      np.asarray(wv)[..., 0, :][com_w])


def test_scan_write_conflict_aborts_scanner_then_loop_converges(cfg, layout):
    """Lane X scans a range; lane Y (another node) commits a write INTO that
    range in the same protocol round.  The scanner must observe the leaf
    lock/version change at validation and abort (cause: validate); the retry
    loop then converges both."""
    # a roomier scan bound: the 6-key range may fragment across more leaves
    # than the module fixture's 4 once the conflicting insert splits one
    cfg = bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4,
                         max_scan_leaves=8)
    layout = bt.build_layout(cfg)
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(23)
    allk = np.sort(distinct_uint32(rng, N * 8, 0, 2**31))
    keys = jnp.asarray(allk.reshape(N, 8), jnp.uint32)
    state = insert(t, state, cfg, layout, keys)
    meta = bt.local_meta(cfg, layout, state)

    # node 0 lane 0 scans [allk[0], allk[5]]; node 1 lane 0 writes a fresh
    # key inside that range; everyone else idles
    lo = jnp.zeros((N, 1), jnp.uint32).at[0, 0].set(jnp.uint32(allk[0]))
    hi = jnp.zeros((N, 1), jnp.uint32)          # lo > hi = no scan
    hi = hi.at[0, 0].set(jnp.uint32(allk[5]))
    wkey = jnp.uint32(allk[2] + 1) if allk[2] + 1 != allk[3] \
        else jnp.uint32(allk[2] + 2)
    wk = jnp.zeros((N, 1, 1), jnp.uint32)
    wen = jnp.zeros((N, 1, 1), bool).at[1, 0, 0].set(True)
    wk = wk.at[1, 0, 0].set(wkey)
    wv = value_for(wk)

    _, res = txm.run_scan_transactions(
        t, state, cfg, layout, scan_lo=lo, scan_hi=hi, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen)
    r = np.asarray
    assert r(res.committed)[1, 0], "the writer must commit"
    assert not r(res.committed)[0, 0], "the scanner must abort"
    assert r(res.aborted_validate)[0, 0], "cause must be validate (OCC)"

    st2, _, resL = txl.scan_loop(
        t, state, cfg, layout, scan_lo=lo, scan_hi=hi, meta=meta,
        write_keys=wk, write_values=wv, write_enabled=wen, max_rounds=4)
    assert bool(r(resL.committed).all()), "the loop must converge everyone"
    assert int(r(resL.round_abort_validate)[0]) > 0
    # the converged scan INCLUDES the concurrently committed key
    got = sorted(r(resL.scan_keys)[0, 0][r(resL.scan_mask)[0, 0]].tolist())
    exp = sorted([int(k) for k in allk[:6]] + [int(wkey)])
    assert got == exp


def test_truncated_scan_reported_never_clipped(cfg, layout):
    """A range needing more than max_scan_leaves leaves is REPORTED truncated
    (parked by the loop), never returned as a silently clipped success."""
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    # 40 dense keys, ALL inside node 0's partition: splits it into far more
    # than max_scan_leaves leaves
    p_lo = int(np.asarray(bt.partition_bounds(
        cfg, jnp.arange(N, dtype=jnp.int32))[0])[0])
    keys = jnp.asarray((p_lo + 64 + 8 * np.arange(40)).reshape(N, 10),
                       jnp.uint32)
    h = bt.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, keys),
        bt.make_record(W.OP_BT_INSERT, keys, jnp.zeros_like(keys),
                       value=value_for(keys)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    meta = bt.local_meta(cfg, layout, state)
    nleaf0 = int(np.asarray(state["arena"])[0, layout["nleaf"].base])
    assert nleaf0 > cfg.max_scan_leaves, "setup must split past the bound"

    lo = jnp.zeros((N, 1), jnp.uint32).at[0, 0].set(jnp.uint32(p_lo))
    hi = jnp.zeros((N, 1), jnp.uint32).at[0, 0].set(
        jnp.uint32(p_lo + 64 + 8 * 39))
    _, res = txm.run_scan_transactions(t, state, cfg, layout, scan_lo=lo,
                                       scan_hi=hi, meta=meta)
    r = np.asarray
    assert r(res.truncated)[0, 0] and not r(res.committed)[0, 0]
    _, _, resL = txl.scan_loop(t, state, cfg, layout, scan_lo=lo, scan_hi=hi,
                               meta=meta, max_rounds=3)
    assert r(resL.truncated)[0, 0] and not r(resL.committed)[0, 0]


def test_backup_installs_never_corrupt_the_primary_tree(cfg, layout):
    """Regression (code review): ring placement makes EVERY replicated key
    sit outside the backup node's partition.  A storm of OP_BT_BACKUP
    installs — enough to split repeatedly — must land in the backup node's
    full-range backup tree and leave its primary fence chain, separators
    and OWN committed keys fully intact."""
    from tests.test_btree import node_keys, walk_leaves
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    own = node_keys(cfg, 8, seed=41)
    state = insert(t, state, cfg, layout, own)

    # node 0's keys backed up onto node 1: 16 foreign installs, far below
    # node 1's partition, splitting the backup tree several times
    rng = np.random.RandomState(43)
    part = int(np.asarray(bt.partition_bounds(
        cfg, jnp.arange(N, dtype=jnp.int32))[0])[1])   # node 1's lo bound
    foreign = distinct_uint32(rng, N * 16, 0, part // 2).reshape(N, 16)
    fk = jnp.asarray(foreign, jnp.uint32)
    dest = jnp.ones_like(fk, dtype=jnp.int32)          # all to node 1
    h = bt.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, dest, bt.make_record(W.OP_BT_BACKUP, fk,
                                       jnp.zeros_like(fk),
                                       value=value_for(fk)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    bnleaf = int(np.asarray(state["arena"])[1, layout["bnleaf"].base])
    assert bnleaf > 1, "setup must split the backup tree"
    assert int(np.asarray(state["arena"])[1, layout["nleaf"].base]) == \
        int(np.asarray(state["arena"])[0, layout["nleaf"].base]), \
        "backup installs must not allocate PRIMARY leaves"

    # primary invariants and node 1's own keys survive untouched
    for n in range(N):
        assert walk_leaves(state, cfg, layout, n) == \
            sorted(int(k) for k in np.asarray(own)[n])
    state, rep, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, own),
        bt.make_record(W.OP_BT_LOOKUP, own, jnp.zeros_like(own)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all(), \
        "own committed keys must stay reachable after backup traffic"
    # ... and the backup copies are served (from the backup tree) on node 1
    state, rep, _, _ = R.rpc_call(
        t, state, dest, bt.make_record(W.OP_BT_LOOKUP, fk,
                                       jnp.zeros_like(fk)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    np.testing.assert_array_equal(np.asarray(rep[..., 3:]),
                                  np.asarray(value_for(fk)))
