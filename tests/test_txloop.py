"""Multi-round transaction engine (txloop), coalesced wire accounting, the
rpc overflow-status regression, and the hybrid cache-hit slot regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid as hy
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.core.txloop import tx_loop
from repro.testing.workloads import value_for, zipf_write_keys

N = 4


@pytest.fixture(scope="module")
def cfg():
    return ht.HashTableConfig(n_nodes=N, n_buckets=64, bucket_width=2,
                              n_overflow=64, max_chain=6)


@pytest.fixture(scope="module")
def layout(cfg):
    return ht.build_layout(cfg)


def insert_keys(t, state, cfg, layout, klo, khi):
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi,
                                       value=value_for(klo)), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    return state


# ---------------------------------------------------------------------------
# Acceptance: tx_loop beats single-shot under skew, with coherent metrics
# ---------------------------------------------------------------------------
def test_txloop_converges_on_skewed_writes(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 8
    hot, klo, khi = zipf_write_keys(N, B, seed=1)
    state = insert_keys(t, state, cfg, layout, jnp.tile(hot[None], (N, 1)),
                        jnp.zeros((N, hot.shape[0]), jnp.uint32))
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo + jnp.uint32(5))

    s1, _, single = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv)
    n_single = int(np.asarray(single.committed).sum())

    s2, _, res = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                         write_values=wv, max_rounds=6)
    n_loop = int(np.asarray(res.committed).sum())

    # the whole point: retries commit strictly more work under contention
    assert n_loop > n_single, (n_loop, n_single)
    # per-round accounting is exact: every attempt commits or aborts with
    # exactly one cause
    com = np.asarray(res.round_committed)
    att = np.asarray(res.round_attempts)
    a_l = np.asarray(res.round_abort_lock)
    a_v = np.asarray(res.round_abort_validate)
    a_o = np.asarray(res.round_abort_overflow)
    np.testing.assert_array_equal(att, com + a_l + a_v + a_o)
    assert com.sum() == n_loop
    # round 0 is the single-shot protocol; later rounds only retry survivors
    assert com[0] == n_single
    assert int(np.asarray(res.round_retries)[0]) == 0
    assert int(np.asarray(res.round_retries)[1]) == att[1] == att[0] - com[0]
    assert a_l[0] > 0, "skewed writes must produce lock-race aborts"
    # commit_round is consistent with the committed mask
    cr = np.asarray(res.commit_round)
    assert ((cr >= 0) == np.asarray(res.committed)).all()
    # coalesced wire: strictly fewer messages than the per-op count (every
    # round sends many lanes to few destinations)
    msgs = float(res.metrics.wire.messages)
    ops = float(res.metrics.wire.ops)
    assert msgs <= 2.0 * ops
    assert msgs < 2.0 * ops, "trace has multiple lanes per (src,dst) pair"


def test_txloop_single_round_matches_single_shot(cfg, layout):
    """Round 0 uses the identity slot order, so max_rounds=1 IS run_transactions."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 6
    rng = np.random.RandomState(3)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo)
    s1, _, single = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv)
    s2, _, loop = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                          write_values=wv, max_rounds=1)
    np.testing.assert_array_equal(np.asarray(single.committed),
                                  np.asarray(loop.committed))
    np.testing.assert_array_equal(np.asarray(s1["arena"]), np.asarray(s2["arena"]))


def test_txloop_drains_backpressure(cfg, layout):
    """Distinct keys + tiny per-destination capacity: single shot drops lanes
    with ST_NO_SPACE aborts; the loop re-enables them and every lane lands."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 8
    rng = np.random.RandomState(4)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo)
    s1, _, single = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        capacity=2)
    assert int(np.asarray(single.aborted_overflow).sum()) > 0
    s2, _, res = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                         write_values=wv, capacity=2, max_rounds=8)
    assert bool(np.asarray(res.committed).all()), np.asarray(res.committed)
    assert int(np.asarray(res.round_abort_overflow)[0]) > 0


def test_txloop_reads_and_writes(cfg, layout):
    """Mixed read+write lanes: reads from the committing round are returned."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B, Rd = 4, 2
    rng = np.random.RandomState(5)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + 1)), jnp.uint32)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    rk = jnp.stack([klo[..., :Rd], khi[..., :Rd]], -1)
    wk = jnp.stack([klo[..., Rd:], khi[..., Rd:]], -1)
    wv = value_for(klo[..., Rd:] + jnp.uint32(9))
    state, _, res = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                            write_values=wv, max_rounds=4)
    assert bool(np.asarray(res.committed).all())
    assert bool(np.asarray(res.read_found).all())
    np.testing.assert_array_equal(np.asarray(res.read_values),
                                  np.asarray(value_for(klo[..., :Rd])))


def test_txloop_never_commits_undelivered_reads(cfg, layout):
    """Read-only transactions whose read-set lookup was DROPPED by capacity
    back-pressure must abort (cause: overflow) and retry — never report
    committed with a zeroed read of a key that exists."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 8
    rng = np.random.RandomState(8)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    rk = jnp.stack([klo, khi], -1)
    wk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wv = jnp.zeros((N, B, 0, sl.VALUE_WORDS), jnp.uint32)
    # single shot at capacity=1: committed lanes must all have real reads
    s1, _, single = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        capacity=1)
    com = np.asarray(single.committed)
    found = np.asarray(single.read_found)[..., 0]
    assert not com.all(), "capacity=1 must drop some lookups"
    assert found[com].all(), "a committed lane must have its read delivered"
    assert np.asarray(single.aborted_overflow)[~com].all()
    # the loop retries the dropped lanes until every read lands
    s2, _, res = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                         write_values=wv, capacity=1, max_rounds=10)
    assert bool(np.asarray(res.committed).all())
    assert bool(np.asarray(res.read_found).all())


# ---------------------------------------------------------------------------
# Regression: dropped RPCs must not alias success (satellite 2)
# ---------------------------------------------------------------------------
def test_rpc_overflow_reports_dropped(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B, cap = 6, 2
    rng = np.random.RandomState(6)
    # keys are drawn from node 0's own partition: every node hammers the
    # key's legitimate owner, so delivered ops succeed and ONLY capacity
    # decides who is dropped (a non-owner would refuse with ST_WRONG_EPOCH
    # — the placement layer's owner check, tested in test_placement.py)
    pool = rng.randint(0, 2**31, (8 * N * B, 2)).astype(np.uint32)
    part = np.asarray(ht.part_of(cfg, jnp.asarray(pool[:, 0]),
                                 jnp.asarray(pool[:, 1])))
    pool = pool[part == 0][:N * B]
    assert len(pool) == N * B
    klo = jnp.asarray(pool[:, 0].reshape(N, B))
    khi = jnp.asarray(pool[:, 1].reshape(N, B))
    dest = jnp.zeros((N, B), jnp.int32)          # everyone hammers node 0
    h = ht.make_rpc_handler(cfg, layout)
    recs = ht.make_record(R.OP_INSERT, klo, khi, value=value_for(klo))
    state, rep, ovf, _ = R.rpc_call(t, state, dest, recs, h, capacity=cap)
    ovf_np = np.asarray(ovf)
    assert ovf_np.sum() == N * (B - cap)
    # delivered lanes succeeded; dropped lanes say ST_DROPPED — never ST_OK,
    # and never the handler's delivered-but-full ST_NO_SPACE
    st_word = np.asarray(rep[..., 0])
    np.testing.assert_array_equal(st_word[~ovf_np], R.ST_OK)
    np.testing.assert_array_equal(st_word[ovf_np], R.ST_DROPPED)
    # parked lanes are stamped the same way
    state, rep2, _, _ = R.rpc_call(t, state, dest, recs, h, capacity=B,
                                   enabled=jnp.zeros((N, B), bool))
    np.testing.assert_array_equal(np.asarray(rep2[..., 0]), R.ST_DROPPED)


# ---------------------------------------------------------------------------
# Regression: cache-hit reads accept only the exact cached slot (satellite 3)
# ---------------------------------------------------------------------------
def test_lookup_end_cache_hit_exact_slot_only():
    cfg2 = ht.HashTableConfig(n_nodes=1, n_buckets=4, bucket_width=2,
                              n_overflow=8)
    val = jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)
    hit_slot = sl.pack_slot(7, 9, 4, 0, sl.NULL_PTR, val)
    other = sl.make_empty_slot()
    # cached slot (window pos 0) stale/empty; the NEIGHBOUR slot — which
    # belongs to a different bucket — happens to hold the key
    buf = jnp.concatenate([other, hit_slot])[None]
    klo, khi = jnp.uint32([7]), jnp.uint32([9])
    ok_miss, _, idx_miss = ht.lookup_end(cfg2, buf, klo, khi)
    assert bool(ok_miss[0]) and int(idx_miss[0]) == 1  # bucket read: fine
    ok_hit, _, _ = ht.lookup_end(cfg2, buf, klo, khi,
                                 cache_hit=jnp.asarray([True]))
    assert not bool(ok_hit[0]), \
        "cache-hit window must not match beyond the exact cached slot"
    # the exact slot matching is still accepted on a hit
    buf2 = jnp.concatenate([hit_slot, other])[None]
    ok2, val2, idx2 = ht.lookup_end(cfg2, buf2, klo, khi,
                                    cache_hit=jnp.asarray([True]))
    assert bool(ok2[0]) and int(idx2[0]) == 0
    np.testing.assert_array_equal(np.asarray(val2[0]), np.asarray(val))


def test_hybrid_cached_lookup_pins_slot_idx():
    """Cache-hit and cache-miss lookups must agree on slot_idx (and values),
    including overflow-chained keys whose cached slot sits near the region
    boundary with bucket_width > 1."""
    cfg2 = ht.HashTableConfig(n_nodes=1, n_buckets=1, bucket_width=2,
                              n_overflow=16, max_chain=18, cache_slots=256)
    layout2 = ht.build_layout(cfg2)
    t = SimTransport(1)
    state = ht.init_cluster_state(cfg2)
    B = 10   # one bucket of width 2 -> 8 keys live in the overflow chain
    rng = np.random.RandomState(7)
    klo = jnp.asarray(rng.randint(0, 2**31, (1, B)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (1, B)), jnp.uint32)
    state = insert_keys(t, state, cfg2, layout2, klo, khi)

    cache = jax.tree.map(lambda x: x[None].repeat(1, 0),
                         ht.init_cache(cfg2))
    # cold pass learns exact addresses (mostly via RPC fallback)
    state, cache, f0, v0, _, _, sidx0, _, m0 = hy.hybrid_lookup(
        t, state, klo, khi, cfg2, layout2, cache=cache)
    assert bool(f0.all())
    # warm pass: cache hits serve the exact slot one-sided
    state, cache, f1, v1, _, _, sidx1, _, m1 = hy.hybrid_lookup(
        t, state, klo, khi, cfg2, layout2, cache=cache)
    assert bool(f1.all())
    np.testing.assert_array_equal(np.asarray(sidx1), np.asarray(sidx0))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v0))
    # cached (exact-slot) reads beat the cold pass's one-sided success rate
    assert float(m1.onesided_success) > float(m0.onesided_success)
    # and every slot index stays inside the slots region
    assert int(np.asarray(sidx1).max()) < cfg2.n_slots
    # uncached truth agrees
    state, _, f2, v2, _, _, sidx2, *_ = hy.hybrid_lookup(
        t, state, klo, khi, cfg2, layout2, cache=None)
    np.testing.assert_array_equal(np.asarray(sidx2), np.asarray(sidx1))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))
