"""Checkpoint atomicity + elastic restore + data-pipeline determinism."""

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ShapeConfig
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, synthetic_batch, synthetic_tokens
from repro.parallel.sharding import Topology
from repro.train.step import init_train_state, make_train_state_specs


def test_save_restore_roundtrip(tmp_path):
    cfg = ARCHS["qwen1.5-4b"].smoke()
    state = init_train_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path / "ck")
    mgr.save(10, state)
    assert mgr.latest_committed_step() == 10
    step, restored = mgr.restore()
    assert step == 10
    flat_a = {jax.tree_util.keystr(k): v for k, v
              in jax.tree_util.tree_leaves_with_path(state)}
    flat_b = {jax.tree_util.keystr(k): v for k, v
              in jax.tree_util.tree_leaves_with_path(restored)}
    assert set(flat_a) == set(flat_b)
    for k in flat_a:
        np.testing.assert_array_equal(np.asarray(flat_a[k], np.float32),
                                      np.asarray(flat_b[k], np.float32))


def test_commit_is_atomic_under_partial_write(tmp_path):
    """A leftover .tmp dir (simulated crash) must not shadow the last good
    checkpoint."""
    cfg = ARCHS["qwen1.5-4b"].smoke()
    state = init_train_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path / "ck")
    mgr.save(1, state)
    # crash mid-write of step 2: fabricate a stale tmp dir
    (tmp_path / "ck" / "step_00000002.tmp").mkdir()
    step, _ = mgr.restore()
    assert step == 1
    assert mgr.latest_committed_step() == 1


def test_elastic_restore_to_new_topology(tmp_path):
    """Restore re-device_puts against a different topology (mesh change)."""
    cfg = ARCHS["granite-moe-1b-a400m"].smoke()
    state = init_train_state(cfg, jax.random.key(1))
    mgr = CheckpointManager(tmp_path / "ck")
    mgr.save(5, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    topo = Topology(mesh)
    specs = make_train_state_specs(cfg)
    step, restored = mgr.restore(topo=topo, spec_tree=specs)
    assert step == 5
    leaf = restored["params"]["embed"]
    assert leaf.shape == (cfg.vocab_padded, cfg.d_model)


def test_checkpoint_gc_keeps_last_k(tmp_path):
    cfg = ARCHS["qwen1.5-4b"].smoke()
    state = init_train_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(tmp_path / "ck", keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    kept = sorted(p.name for p in (tmp_path / "ck").glob("step_*"))
    assert kept == ["step_00000003", "step_00000004"]


def test_data_pipeline_deterministic_and_resumable():
    cfg = ARCHS["glm4-9b"].smoke()
    shape = ShapeConfig("t", 64, 4, "train")
    dc = DataConfig(seed=3)
    a = synthetic_batch(cfg, shape, dc, step=7)
    b = synthetic_batch(cfg, shape, dc, step=7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = synthetic_batch(cfg, shape, dc, step=8)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    toks = synthetic_tokens(dc, 0, 2, 128, cfg.vocab_size)
    assert toks.min() >= 1 and toks.max() < cfg.vocab_size
