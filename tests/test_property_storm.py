"""Hypothesis property tests on the Storm dataplane's invariants.

Runs under real hypothesis when installed; otherwise falls back to the
fixed-sample stub in repro.testing so collection never dies and the
invariants keep being exercised (`pytest.importorskip` would silently drop
this whole suite on the container image, which has no hypothesis)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import hybrid as hy
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport, pick_replies, route_by_dest


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 24),
    n_dst=st.integers(1, 6),
    cap=st.integers(1, 24),
    seed=st.integers(0, 1000),
)
def test_routing_conservation(b, n_dst, cap, seed):
    """Every lane is either placed in exactly one live cell or overflowed;
    live cells reproduce payloads exactly (no loss, no duplication)."""
    rng = np.random.RandomState(seed)
    dest = jnp.asarray(rng.randint(0, n_dst, b), jnp.int32)
    payload = jnp.asarray(rng.randint(0, 2**31, (b, 2)), jnp.uint32)
    buf, mask, pos, ovf = route_by_dest(dest, payload, n_dst, cap)
    assert int(mask.sum()) + int(ovf.sum()) == b
    out = pick_replies(buf, dest, pos, ovf)
    ok = ~np.asarray(ovf)
    np.testing.assert_array_equal(np.asarray(out)[ok], np.asarray(payload)[ok])
    # per-destination occupancy never exceeds capacity
    assert int(mask.sum(axis=1).max()) <= cap


@settings(max_examples=4, deadline=None)
@given(
    n_keys=st.sampled_from([8, 24]),     # fixed sizes -> jit cache hits
    n_buckets=st.sampled_from([16]),
    width=st.sampled_from([1, 2]),
    seed=st.integers(0, 100),
)
def test_insert_lookup_delete_invariant(n_keys, n_buckets, width, seed):
    """insert(k,v) -> lookup(k)==v; delete(k) -> lookup misses; other keys
    unaffected — regardless of collisions/chaining."""
    cfg = ht.HashTableConfig(n_nodes=2, n_buckets=n_buckets,
                             bucket_width=width, n_overflow=32,
                             max_chain=26)
    layout = ht.build_layout(cfg)
    t = SimTransport(2)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(seed)
    # unique keys (offset stride guarantees uniqueness without a 2^31 perm)
    k = (rng.randint(0, 2**20, size=2 * n_keys).astype(np.uint32) * 2048
         + np.arange(2 * n_keys, dtype=np.uint32))
    klo = jnp.asarray(k.reshape(2, n_keys))
    khi = jnp.zeros_like(klo)
    vals = sl._mix32(klo[..., None] + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32))
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    h = ht.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)

    state, _, found, value, *_ = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=True)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(value), np.asarray(vals))

    # delete the first half on each node
    half = max(n_keys // 2, 1)
    dl, dh = klo[:, :half], khi[:, :half]
    dnode, _, _ = ht.lookup_start(cfg, layout, dl, dh)
    state, rep, _, _ = R.rpc_call(
        t, state, dnode, ht.make_record(R.OP_DELETE, dl, dh), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    state, _, found2, value2, *_ = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=True)
    f2 = np.asarray(found2)
    assert not f2[:, :half].any(), "deleted keys must miss"
    assert f2[:, half:].all(), "surviving keys must still hit"
    np.testing.assert_array_equal(np.asarray(value2)[:, half:],
                                  np.asarray(vals)[:, half:])


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 50), lanes=st.sampled_from([3]))
def test_tx_single_winner_per_contended_key(seed, lanes):
    """OCC invariant: any number of lanes writing the same key -> exactly one
    commit per round, and the slot is consistent (even version, unlocked)."""
    N = 2
    cfg = ht.HashTableConfig(n_nodes=N, n_buckets=16, bucket_width=2,
                             n_overflow=16)
    layout = ht.build_layout(cfg)
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    key = jnp.full((N, lanes, 1), 777 + seed, jnp.uint32)
    khi = jnp.zeros_like(key)
    wk = jnp.stack([key, khi], axis=-1)
    state, _, res = txm.run_transactions(
        t, state, cfg, layout,
        read_keys=jnp.zeros((N, lanes, 0, 2), jnp.uint32),
        write_keys=wk, write_values=sl._mix32(
            key + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)))
    assert int(np.asarray(res.committed).sum()) == 1
    # post-state: the key is readable, even-version, unlocked
    state, _, found, _, ver, *_ = hy.hybrid_lookup(
        t, state, key[:, :, 0], khi[:, :, 0], cfg, layout)
    assert bool(found.all())
    v = np.asarray(ver)
    assert (v % 2 == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 24),
    n_dst=st.integers(1, 4),
    cap=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_route_backpressure_retry_delivers_all(b, n_dst, cap, seed):
    """Back-pressure invariants: (1) overflowed lanes never clobber live
    cells — every delivered payload is byte-exact; (2) retry rounds that
    re-enable exactly the overflow mask eventually deliver EVERY lane,
    because parked (already-delivered) lanes no longer consume capacity."""
    rng = np.random.RandomState(seed)
    dest = jnp.asarray(rng.randint(0, n_dst, b), jnp.int32)
    payload = jnp.asarray(rng.randint(0, 2**31, (b, 2)), jnp.uint32)
    pending = jnp.ones((b,), bool)
    delivered = np.zeros((b,), bool)
    max_rounds = -(-b // cap) + 1
    for _ in range(max_rounds):
        buf, mask, pos, ovf = route_by_dest(dest, payload, n_dst, cap,
                                            enabled=pending)
        # live cells reproduce their lane's payload exactly (no clobber)
        out = pick_replies(buf, dest, pos, ovf)
        sent = np.asarray(pending & ~ovf)
        np.testing.assert_array_equal(np.asarray(out)[sent],
                                      np.asarray(payload)[sent])
        assert int(mask.sum(axis=1).max()) <= cap
        assert not (delivered & sent).any(), "parked lanes must stay parked"
        delivered |= sent
        pending = ovf          # next round re-enables exactly the overflow
        if not bool(pending.any()):
            break
    assert delivered.all(), f"{delivered.sum()}/{b} delivered in {max_rounds}"


@settings(max_examples=20, deadline=None)
@given(klo=st.integers(0, 2**31), khi=st.integers(0, 2**31))
def test_hash_stability_and_range(klo, khi):
    cfg = ht.HashTableConfig(n_nodes=7, n_buckets=64, bucket_width=1,
                             n_overflow=8)
    n1, b1 = ht.home_of(cfg, jnp.uint32(klo), jnp.uint32(khi))
    n2, b2 = ht.home_of(cfg, jnp.uint32(klo), jnp.uint32(khi))
    assert int(n1) == int(n2) and int(b1) == int(b2)
    assert 0 <= int(n1) < 7 and 0 <= int(b1) < 64
