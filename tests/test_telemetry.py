"""Flight-recorder telemetry (core/telemetry.py).

The load-bearing property: ``telemetry=None`` is bit-identical to a
recorder-free build, and ``telemetry=on`` only ever READS protocol values —
committed state, abort causes and WireStats must be bit-identical either
way, including under send-queue back-pressure and replication fan-out.
Plus: the WireStats field-driven zero()/__add__ regression, the per-dest
wire tails' exact reconciliation with the scalar accounting, drop-on-full
buffer saturation, and the export layers.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rpc as R
from repro.core import telemetry as T
from repro.core import transport as tp
from repro.core import txloop as txl
from repro.core import wireproto as W
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.replication import ReplicaConfig
from repro.core.transport import SimTransport, WireStats
from repro.testing.workloads import distinct_uint32, value_for, zipf_write_keys

N = 4


# ---------------------------------------------------------------------------
# WireStats: field-driven zero()/__add__ (regression for the 7-positional-
# zeros construction that silently misassigned any newly added field)
# ---------------------------------------------------------------------------
def test_wirestats_zero_add_roundtrip_every_field():
    fields = dataclasses.fields(WireStats)
    z = WireStats.zero() + WireStats.zero()
    for f in fields:
        assert float(getattr(z, f.name)) == 0.0, f"zero()+zero() leaked {f.name}"
    # distinct value per field: addition must round-trip each one by NAME
    w = WireStats(**{f.name: jnp.float32(i + 1.0)
                     for i, f in enumerate(fields)})
    s = w + WireStats.zero()
    for i, f in enumerate(fields):
        assert float(getattr(s, f.name)) == i + 1.0, \
            f"zero() + w misassigned {f.name}"
    d = w + w
    for i, f in enumerate(fields):
        assert float(getattr(d, f.name)) == 2.0 * (i + 1.0)


def test_per_dest_wire_reconciles_with_scalar_accounting():
    rng = np.random.RandomState(3)
    n_src, n_dst = 4, 5
    masks = [jnp.asarray(rng.rand(n_src, n_dst, c) < 0.4)
             for c in (3, 2)]
    req_w, rep_w = [4, 7], [2, 0]
    msgs, byts = tp.per_dest_wire(masks, req_w, rep_w)
    assert msgs.shape == (n_dst,) and byts.shape == (n_dst,)
    scalar = tp.wire_for_classes(masks, req_w, rep_w)
    assert float(jnp.sum(msgs)) == float(scalar.messages)
    assert float(jnp.sum(byts)) == float(scalar.total_bytes)


# ---------------------------------------------------------------------------
# tx_loop / scan_loop equivalence suite: telemetry on vs None bit-identical
# ---------------------------------------------------------------------------
def _ht_cluster(seed=1, B=8):
    cfg = ht.HashTableConfig(n_nodes=N, n_buckets=64, bucket_width=2,
                             n_overflow=64, max_chain=6)
    layout = ht.build_layout(cfg)
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    hot, klo, khi = zipf_write_keys(N, B, seed=seed)
    h = ht.make_rpc_handler(cfg, layout)
    kl = jnp.tile(hot[None], (N, 1))
    kh = jnp.zeros((N, hot.shape[0]), jnp.uint32)
    node, _, _ = ht.lookup_start(cfg, layout, kl, kh)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, kl, kh,
                                       value=value_for(kl)), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo + jnp.uint32(5))
    return cfg, layout, t, state, rk, wk, wv


def _assert_equiv(off, on):
    for a, b in zip(jax.tree.leaves(off), jax.tree.leaves(on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("capacity,rep_f", [(None, 0), (2, 0), (None, 1)],
                         ids=["plain", "backpressure", "f1"])
def test_tx_loop_telemetry_equivalence(capacity, rep_f):
    cfg, layout, t, state, rk, wk, wv = _ht_cluster()
    rep = ReplicaConfig(N, rep_f) if rep_f else None
    kw = dict(read_keys=rk, write_keys=wk, write_values=wv, max_rounds=5,
              capacity=capacity, rep=rep)
    s0, c0, r0 = txl.tx_loop(t, state, cfg, layout, **kw)
    s1, c1, r1, tel = txl.tx_loop(t, state, cfg, layout, **kw,
                                  telemetry=T.TelemetryConfig())
    # committed state, abort causes and WireStats all bit-identical
    _assert_equiv((s0, r0), (s1, r1))
    assert int(tel.trace.n) > 0 and int(tel.trace.dropped) == 0
    lat = np.asarray(tel.lane_latency_us)
    assert lat.shape == np.asarray(r1.committed).shape
    assert np.isfinite(lat).all() and (lat > 0).all()


def _bt_cluster(seed=17, B=6):
    cfg = bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4,
                         max_scan_leaves=4)
    layout = bt.build_layout(cfg)
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(seed)
    allk = np.sort(distinct_uint32(rng, N * 12).astype(np.uint64))
    keys = jnp.asarray(allk.reshape(N, 12), jnp.uint32)
    h = bt.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, keys),
        bt.make_record(W.OP_BT_INSERT, keys, jnp.zeros_like(keys),
                       value=value_for(keys)), h)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    lo_i = rng.randint(0, N * 12 - 6, size=(N, B))
    lo = jnp.asarray(allk[lo_i], jnp.uint32)
    hi = jnp.asarray(allk[lo_i + 5], jnp.uint32)
    wk = jnp.asarray(allk[rng.randint(0, N * 12, size=(N, B, 1))], jnp.uint32)
    wv = value_for(wk + jnp.uint32(9))
    return cfg, layout, t, state, lo, hi, wk, wv


def test_scan_loop_telemetry_equivalence():
    cfg, layout, t, state, lo, hi, wk, wv = _bt_cluster()
    kw = dict(scan_lo=lo, scan_hi=hi, write_keys=wk, write_values=wv,
              max_rounds=3)
    s0, m0, r0 = txl.scan_loop(t, state, cfg, layout, **kw)
    s1, m1, r1, tel = txl.scan_loop(t, state, cfg, layout, **kw,
                                    telemetry=T.TelemetryConfig())
    _assert_equiv((s0, m0, r0), (s1, m1, r1))
    ev = T.events(tel.trace)
    # the up-front directory fetch is stamped round -1
    assert int((ev[:, T.EV_ROUND] < 0).sum()) == 1


# ---------------------------------------------------------------------------
# Trace content: schema invariants, per-dest reconciliation, saturation
# ---------------------------------------------------------------------------
def test_trace_rows_reconcile_and_price():
    cfg, layout, t, state, rk, wk, wv = _ht_cluster()
    _, _, res, tel = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                                 write_keys=wk, write_values=wv, max_rounds=4,
                                 telemetry=T.TelemetryConfig())
    ev = T.events(tel.trace)
    assert ev.shape[1] == T.EV_WORDS + 2 * N
    phases = set(int(r[T.EV_PHASE]) for r in ev)
    assert {T.PH_READ, T.PH_LOCK, T.PH_COMMIT, T.PH_SUMMARY} <= phases
    # per-row: the per-dest msgs tail sums to the scalar column exactly
    np.testing.assert_allclose(ev[:, T.EV_WORDS:T.EV_WORDS + N].sum(1),
                               ev[:, T.EV_MSGS], rtol=1e-6)
    # ...and totals match the loop's aggregated WireStats
    assert ev[:, T.EV_MSGS].sum() == pytest.approx(
        float(res.metrics.wire.messages))
    assert ev[:, T.EV_WORDS + N:].sum() == pytest.approx(
        float(res.metrics.wire.total_bytes))
    # summary rows carry the abort vector the loop reports
    summ = ev[ev[:, T.EV_PHASE] == T.PH_SUMMARY]
    assert summ[:, T.EV_COMMITTED].sum() == pytest.approx(
        float(jnp.sum(res.round_committed)))
    assert summ[:, T.EV_AB_LOCK].sum() == pytest.approx(
        float(jnp.sum(res.round_abort_lock)))


def test_trace_buffer_saturates_without_error():
    cfg, layout, t, state, rk, wk, wv = _ht_cluster()
    s0, _, r0 = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                            write_keys=wk, write_values=wv, max_rounds=4)
    s1, _, r1, tel = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                                 write_keys=wk, write_values=wv, max_rounds=4,
                                 telemetry=T.TelemetryConfig(capacity=3))
    # a full buffer drops events — it never perturbs the protocol
    _assert_equiv((s0, r0), (s1, r1))
    assert int(tel.trace.n) == 3 and int(tel.trace.dropped) > 0


def test_export_trace_and_summaries():
    cfg, layout, t, state, rk, wk, wv = _ht_cluster()
    _, _, res, tel = txl.tx_loop(t, state, cfg, layout, read_keys=rk,
                                 write_keys=wk, write_values=wv, max_rounds=4,
                                 telemetry=T.TelemetryConfig())
    doc = T.export_trace(tel.trace)
    json.dumps(doc)                       # Perfetto-loadable == valid JSON
    kinds = {e["ph"] for e in doc["traceEvents"]}
    assert kinds == {"M", "X", "C"}
    assert doc["otherData"]["dropped"] == 0
    ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] == "X"]
    assert ts == sorted(ts), "modeled timeline must be monotone"
    s = T.summarize([1.0, 2.0, 3.0, 4.0])
    assert s["p50"] == pytest.approx(2.5) and s["mean"] == pytest.approx(2.5)
    assert s["p50"] <= s["p90"] <= s["p99"]
    assert all(np.isnan(v) for v in T.summarize([]).values())
    paths = T.latency_by_path(tel.lane_latency_us, res.committed,
                              res.commit_round)
    assert "committed" in paths
    for grp in paths.values():
        assert grp["p50"] <= grp["p99"]


def test_metrics_registry():
    reg = T.MetricsRegistry()
    reg.incr("a.count")
    reg.incr("a.count", 2.5)
    reg.set("b", 7)
    reg.observe("lat_us", [1.0, 9.0])
    d = reg.as_dict()
    assert d["a.count"] == 3.5 and d["b"] == 7.0
    assert d["lat_us.p50"] == pytest.approx(5.0)
    assert reg.get("missing", 1.25) == 1.25
