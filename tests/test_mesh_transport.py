"""MeshTransport (shard_map/all_to_all) must agree with SimTransport.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=8 so the main
test session keeps its single-device view (per the dry-run isolation rule).
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import rpc as R
    from repro.core import slots as sl
    from repro.core import onesided as osd
    from repro.core import hybrid as hy
    from repro.core.datastructs import hashtable as ht
    from repro.core.transport import SimTransport, MeshTransport

    N, B = 8, 16
    cfg = ht.HashTableConfig(n_nodes=N, n_buckets=32, bucket_width=2,
                             n_overflow=32)
    layout = ht.build_layout(cfg)
    rng = np.random.RandomState(0)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B)), jnp.uint32)
    vals = sl._mix32(klo[..., None] + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32))
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    h = ht.make_rpc_handler(cfg, layout)

    # --- simulator reference -------------------------------------------
    ts = SimTransport(N)
    s_sim = ht.init_cluster_state(cfg)
    s_sim, rep_sim, _, _ = R.rpc_call(
        ts, s_sim, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    s_sim, _, f_sim, v_sim, *_ = hy.hybrid_lookup(
        ts, s_sim, klo, khi, cfg, layout)

    # --- mesh execution --------------------------------------------------
    mesh = jax.make_mesh((8,), ("node",))
    tm = MeshTransport(N, axis_name="node")
    sh = NamedSharding(mesh, P("node"))

    def put(tree):
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)

    def run(state, node, klo, khi, vals):
        recs = ht.make_record(R.OP_INSERT, klo, khi, value=vals)
        state, rep, _, _ = R.rpc_call(tm, state, node, recs, h)
        state, _, found, value, *_ = hy.hybrid_lookup(
            tm, state, klo, khi, cfg, layout)
        return rep, found, value

    if hasattr(jax, "shard_map"):          # jax >= 0.6: check_vma
        smap, smap_kw = jax.shard_map, {"check_vma": False}
    else:                                  # older jax: experimental, check_rep
        from jax.experimental.shard_map import shard_map as smap
        smap_kw = {"check_rep": False}
    fn = jax.jit(smap(
        run, mesh=mesh,
        in_specs=(P("node"), P("node"), P("node"), P("node"), P("node")),
        out_specs=(P("node"), P("node"), P("node")), **smap_kw))
    s_mesh = put(ht.init_cluster_state(cfg))
    rep_m, f_m, v_m = fn(s_mesh, put(node), put(klo), put(khi), put(vals))

    np.testing.assert_array_equal(np.asarray(rep_m[..., 0]),
                                  np.asarray(rep_sim[..., 0]))
    np.testing.assert_array_equal(np.asarray(f_m), np.asarray(f_sim))
    np.testing.assert_array_equal(np.asarray(v_m), np.asarray(v_sim))
    print("MESH_OK")
""")


def test_mesh_transport_matches_simulator():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "/root/repo/src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=560, env=env)
    assert "MESH_OK" in res.stdout, res.stdout + "\n" + res.stderr[-3000:]
