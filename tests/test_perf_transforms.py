"""The §Perf transforms must be EXACT-equivalent (same math, new schedule)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.transformer import RunOptions
from repro.parallel.sharding import Topology, WIDE_DP_RULES, init_params

SHAPE = ShapeConfig("t", 64, 2, "train")


def topo():
    return Topology(jax.make_mesh((1, 1), ("data", "model")))


def test_pad_heads_is_exact():
    """qwen-style head counts: padded-head attention == baseline logits."""
    cfg = dataclasses.replace(ARCHS["qwen2.5-32b"].smoke(), n_heads=5,
                              n_kv_heads=1)
    t = topo()
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    batch = {"tokens": synthetic_batch(cfg, SHAPE, DataConfig(), 0)["tokens"]}
    base = jax.jit(lambda p, b: api.forward(
        cfg, t, p, b, opts=RunOptions(q_block=32, kv_block=32, remat=False,
                                      pad_heads=False)))(params, batch)
    # force the pad path even on the 1-wide mesh by simulating tp divisibility:
    # run with pad_heads=True on a config whose heads don't divide a fake tp.
    # On the 1-device mesh head_tp is always true, so instead compare the
    # padded math directly through the attention block with a hand-padded tp.
    from repro.models import transformer as tf
    from repro.models import layers as L
    p0 = jax.tree.map(lambda a: a[0], params["layers"])
    h = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16) * 0.1
    pos = jnp.arange(64)
    cos, sin = L.rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    out_base = tf.attention_block(cfg, t, p0, h, cos, sin, window=None,
                                  q_block=32, kv_block=32, pad_heads=False)

    class FakeTopo(Topology):
        @property
        def axis_sizes(self):
            return {"data": 1, "model": 4}   # forces Hq=5 % 4 != 0 -> pad

        def constrain(self, x, *axes):
            return x                          # no real mesh behind it

    ft = FakeTopo(t.mesh)
    out_pad = tf.attention_block(cfg, ft, p0, h, cos, sin, window=None,
                                 q_block=32, kv_block=32, pad_heads=True)
    np.testing.assert_allclose(np.asarray(out_pad, np.float32),
                               np.asarray(out_base, np.float32),
                               atol=2e-2, rtol=1e-2)


def test_moe_modes_agree():
    """rpc and onesided MoE dispatch compute the same function."""
    cfg = dataclasses.replace(ARCHS["granite-moe-1b-a400m"].smoke(),
                              capacity_factor=16.0)
    t = topo()
    params = init_params(api.param_specs(cfg), jax.random.key(2))
    batch = {"tokens": synthetic_batch(cfg, SHAPE, DataConfig(), 0)["tokens"]}
    outs = {}
    for mode in ("rpc", "onesided"):
        # 1-device mesh: moe_ffn falls back to "local"; instead compare the
        # mode implementations directly through moe_ffn on a fake 2-way mesh
        # is heavy — compare through the local path vs forced local (both
        # modes reduce to local on tp=1); the multi-way equivalence is
        # covered by the mesh-transport subprocess test + dry-run compiles.
        outs[mode] = jax.jit(lambda p, b: api.forward(
            cfg, t, p, b, opts=RunOptions(q_block=32, kv_block=32,
                                          remat=False, moe_mode=mode)))(
            params, batch)
    np.testing.assert_allclose(
        np.asarray(outs["rpc"], np.float32),
        np.asarray(outs["onesided"], np.float32), atol=1e-3)


def test_wide_dp_rules_forward_matches_default():
    """WIDE_DP rules change sharding only — same function on 1 device."""
    cfg = ARCHS["mamba2-780m"].smoke()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t_def = Topology(mesh)
    t_wide = Topology(mesh, dict(WIDE_DP_RULES))
    params = init_params(api.param_specs(cfg), jax.random.key(3))
    batch = {"tokens": synthetic_batch(cfg, SHAPE, DataConfig(), 0)["tokens"]}
    opts = RunOptions(q_block=32, kv_block=32, remat=False)
    a = jax.jit(lambda p, b: api.forward(cfg, t_def, p, b, opts=opts))(params, batch)
    b = jax.jit(lambda p, b: api.forward(cfg, t_wide, p, b, opts=opts))(params, batch)
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
