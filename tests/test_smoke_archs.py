"""Per-architecture smoke tests: instantiate a REDUCED same-family config,
run one forward + one train step on CPU, assert shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.transformer import RunOptions
from repro.parallel.sharding import Topology, init_params
from repro.train.step import TrainHparams, init_train_state, make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
OPTS = RunOptions(q_block=32, kv_block=32, remat=False)


def smoke_topo():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return Topology(mesh)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = ARCHS[arch].smoke()
    topo = smoke_topo()
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    batch = synthetic_batch(cfg, SMOKE_SHAPE, DataConfig(), step=0)
    logits = jax.jit(
        lambda p, b: api.forward(cfg, topo, p, b, opts=OPTS))(params, batch)
    assert logits.shape == (SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len,
                            cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_runs_and_loss_finite(arch):
    cfg = ARCHS[arch].smoke()
    topo = smoke_topo()
    state = init_train_state(cfg, jax.random.key(1))
    hp = TrainHparams(opts=OPTS)
    step_fn = jax.jit(make_train_step(cfg, topo, hp))
    batch = synthetic_batch(cfg, SMOKE_SHAPE, DataConfig(), step=0)
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # a second step must also run (donated buffers, schedule)
    batch2 = synthetic_batch(cfg, SMOKE_SHAPE, DataConfig(), step=1)
    state, metrics2 = step_fn(state, batch2)
    assert np.isfinite(float(metrics2["loss"]))


def test_loss_decreases_on_repetitive_stream():
    """End-to-end learnability: tiny dense model on the synthetic stream."""
    cfg = ARCHS["qwen1.5-4b"].smoke()
    topo = smoke_topo()
    state = init_train_state(cfg, jax.random.key(2))
    from repro.optim.adamw import AdamWConfig
    hp = TrainHparams(opts=OPTS, optimizer=AdamWConfig(
        lr=5e-3, warmup_steps=10, weight_decay=0.0))
    step_fn = jax.jit(make_train_step(cfg, topo, hp))
    losses = []
    for s in range(100):
        batch = synthetic_batch(cfg, SMOKE_SHAPE, DataConfig(), step=s)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    # clear, monotone-ish descent on the repetitive stream (tiny model +
    # 100 steps: a few percent — the examples/ drivers train to larger gains)
    assert min(losses[-10:]) < losses[0] * 0.99, (losses[:5], losses[-10:])
    assert min(losses[-10:]) < min(losses[:5]), (losses[:5], losses[-10:])
