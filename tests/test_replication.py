"""Replicated commit dataplane: f=0 bit-identity, zero extra exchange rounds,
byte-equal backup copies (property-tested), the backup back-pressure
regression (overflow surfaces as abort+retry, never a silent drop), and the
kill-node read-failover scenario."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import replication as repl
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.core.txloop import tx_loop
from repro.testing.workloads import value_for

N = 4

WIRE_FIELDS = ("round_trips", "messages", "ops", "req_bytes", "reply_bytes",
               "nic_hit_ops", "nic_penalty_us")
RESULT_FIELDS = ("committed", "read_found", "read_values", "locked_values",
                 "aborted_lock", "aborted_validate", "aborted_overflow")


@pytest.fixture(scope="module")
def cfg():
    return ht.HashTableConfig(n_nodes=N, n_buckets=16, bucket_width=2,
                              n_overflow=64, max_chain=10)


@pytest.fixture(scope="module")
def layout(cfg):
    return ht.build_layout(cfg)


def insert_keys(t, state, cfg, layout, klo, khi):
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi,
                                       value=value_for(klo)), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    return state


def make_workload(seed, B=4, Rd=2, Wr=1):
    rng = np.random.RandomState(seed)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + Wr)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + Wr)), jnp.uint32)
    rk = jnp.stack([klo[..., :Rd], khi[..., :Rd]], -1)
    wk = jnp.stack([klo[..., Rd:], khi[..., Rd:]], -1)
    wv = value_for(klo[..., Rd:] + jnp.uint32(9))
    return klo, khi, rk, wk, wv


def slots_of(state, cfg, layout, node):
    """(n_slots, SLOT_WORDS) numpy view of one node's slot region."""
    srg = layout["slots"]
    arena = np.asarray(state["arena"])
    return arena[node, srg.base:srg.base
                 + cfg.n_slots * sl.SLOT_WORDS].reshape(-1, sl.SLOT_WORDS)


def find_copy(state, cfg, layout, node, klo, khi):
    """The unique slot of (klo, khi) on `node`, or None if absent."""
    slots = slots_of(state, cfg, layout, node)
    m = (slots[:, sl.KEY_LO] == klo) & (slots[:, sl.KEY_HI] == khi)
    assert m.sum() <= 1, f"duplicate copies of one key on node {node}"
    return slots[m.argmax()] if m.any() else None


def assert_replicas_byte_equal(state, cfg, layout, rep, wk, committed_item):
    """Every committed write key: its f backup copies are byte-equal to the
    primary (all slot words except NEXT_PTR, which is per-table chain
    metadata), stable (even version) and unlocked."""
    keep = [j for j in range(sl.SLOT_WORDS) if j != sl.NEXT_PTR]
    wklo = np.asarray(wk[..., 0]).reshape(-1)
    wkhi = np.asarray(wk[..., 1]).reshape(-1)
    com = np.asarray(committed_item).reshape(-1)
    home = np.asarray(ht.home_of(cfg, jnp.asarray(wklo), jnp.asarray(wkhi))[0])
    checked = 0
    for i in range(wklo.size):
        if not com[i]:
            continue
        p = find_copy(state, cfg, layout, home[i], wklo[i], wkhi[i])
        assert p is not None, "committed key missing from its primary"
        assert p[sl.VERSION] % 2 == 0 and p[sl.LOCK] == 0
        for k in range(1, rep.f + 1):
            b_node = int(np.asarray(rep.replica_of(jnp.int32(home[i]), k)))
            b = find_copy(state, cfg, layout, b_node, wklo[i], wkhi[i])
            assert b is not None, \
                f"committed key missing its backup copy {k} on node {b_node}"
            np.testing.assert_array_equal(
                p[keep], b[keep],
                err_msg=f"backup copy {k} differs from the primary")
        checked += 1
    return checked


# ---------------------------------------------------------------------------
# f = 0 is bit-identical to the unreplicated dataplane
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", [False, True])
def test_f0_bit_identical(cfg, layout, fused):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_workload(seed=0)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    s_none, _, r_none = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=fused, rep=None)
    s_f0, _, r_f0 = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=fused, rep=repl.ReplicaConfig(N, 0))
    for f in RESULT_FIELDS + ("round_trips",):
        np.testing.assert_array_equal(np.asarray(getattr(r_none, f)),
                                      np.asarray(getattr(r_f0, f)),
                                      err_msg=f"f=0 changed {f}")
    for f in WIRE_FIELDS:
        assert float(getattr(r_none.metrics.wire, f)) == \
            float(getattr(r_f0.metrics.wire, f)), f"f=0 changed wire {f}"
    np.testing.assert_array_equal(np.asarray(s_none["arena"]),
                                  np.asarray(s_f0["arena"]),
                                  err_msg="f=0 changed committed state")


def test_f0_loop_bit_identical(cfg, layout):
    """The whole retry loop (same PRNG) is bit-identical at f=0."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_workload(seed=1, B=6)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    s_a, _, a = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                        write_values=wv, capacity=2, max_rounds=4)
    s_b, _, b = tx_loop(t, state, cfg, layout, read_keys=rk, write_keys=wk,
                        write_values=wv, capacity=2, max_rounds=4,
                        rep=repl.ReplicaConfig(N, 0))
    np.testing.assert_array_equal(np.asarray(a.committed),
                                  np.asarray(b.committed))
    np.testing.assert_array_equal(np.asarray(a.commit_round),
                                  np.asarray(b.commit_round))
    np.testing.assert_array_equal(np.asarray(s_a["arena"]),
                                  np.asarray(s_b["arena"]))
    assert float(a.metrics.wire.ops) == float(b.metrics.wire.ops)
    assert float(a.round_trips) == float(b.round_trips)


# ---------------------------------------------------------------------------
# f >= 1: zero extra exchange rounds; fused/unfused equivalence holds
# ---------------------------------------------------------------------------
def test_f1_zero_extra_rounds(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_workload(seed=2)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    _, _, r0 = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv)
    for f in (1, 2):
        _, _, rf = txm.run_transactions(
            t, state, cfg, layout, read_keys=rk, write_keys=wk,
            write_values=wv, rep=repl.ReplicaConfig(N, f))
        assert float(rf.round_trips) == float(r0.round_trips), \
            f"f={f} must add ZERO exchange rounds (backups ride the commit round)"
        np.testing.assert_array_equal(np.asarray(rf.committed),
                                      np.asarray(r0.committed))
        # the fan-out IS priced: f backup writes per committed write item
        extra = float(rf.metrics.wire.ops) - float(r0.metrics.wire.ops)
        n_bk = f * int(np.asarray(r0.committed).sum()) * wk.shape[2]
        assert extra == n_bk, (extra, n_bk)


def test_fused_unfused_equivalence_with_replication(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_workload(seed=3)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    rc = repl.ReplicaConfig(N, 2)
    s_ref, _, ref = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=False, rep=rc)
    s_fus, _, fus = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=True, rep=rc)
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(fus, f)))
    np.testing.assert_array_equal(np.asarray(s_ref["arena"]),
                                  np.asarray(s_fus["arena"]))
    assert float(ref.metrics.wire.ops) == float(fus.metrics.wire.ops)
    assert float(fus.round_trips) <= float(ref.round_trips)


# ---------------------------------------------------------------------------
# Property: committed records' backup copies are byte-equal to the primary —
# across seeds, replication factors, schedules, and the lock-insert
# (placeholder) path (write keys are FRESH, so commits insert, not update)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), f=st.sampled_from([1, 2]),
       fused=st.booleans())
def test_backup_copies_byte_equal(seed, f, fused):
    cfg = ht.HashTableConfig(n_nodes=N, n_buckets=16, bucket_width=2,
                             n_overflow=64, max_chain=10)
    layout = ht.build_layout(cfg)
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    klo, khi, rk, wk, wv = make_workload(seed=seed)
    # reads pre-inserted; WRITE keys are fresh -> commit takes the
    # lock-insert placeholder path, whose committed version must still be
    # predictable client-side for the backup image to match
    state = insert_keys(t, state, cfg, layout,
                        klo[..., :2].reshape(N, -1), khi[..., :2].reshape(N, -1))
    rc = repl.ReplicaConfig(N, f)
    state, _, res = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        fused=fused, rep=rc)
    com_item = np.repeat(np.asarray(res.committed), wk.shape[2], axis=-1)
    checked = assert_replicas_byte_equal(state, cfg, layout, rc, wk, com_item)
    assert checked == int(np.asarray(res.committed).sum()) * wk.shape[2]
    assert checked > 0, "vacuous run: nothing committed"


# ---------------------------------------------------------------------------
# Regression: backup writes beyond a destination's send budget must surface
# as the per-lane overflow mask (abort + retry) — never a silent truncation
# ---------------------------------------------------------------------------
def test_backup_overflow_aborts_and_retries(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B, cap = 8, 2
    rng = np.random.RandomState(11)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    state = insert_keys(t, state, cfg, layout,
                        klo.reshape(N, -1), khi.reshape(N, -1))
    rk = jnp.zeros((N, B, 0, 2), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo + jnp.uint32(5))
    # pathological placement: EVERY backup lands on node 0, so each source's
    # backup class overflows its per-destination budget of `cap`
    rc = repl.ReplicaConfig(N, 1, placement=lambda p, i, n: jnp.zeros_like(p))

    _, _, single = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        capacity=cap, rep=rc)
    com = np.asarray(single.committed)
    ovf = np.asarray(single.aborted_overflow)
    assert ovf.sum() > 0, "placement must actually overflow the backup class"
    # no silent truncation: every lane whose backup was dropped is ABORTED
    # with cause overflow, and every lane reported committed has its backup
    s1_state, _, _ = txm.run_transactions(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        capacity=cap, rep=rc)
    checked = assert_replicas_byte_equal(s1_state, cfg, layout, rc, wk, com)
    assert checked == com.sum()

    # ... and the retry loop drains the back-pressure: every lane eventually
    # commits WITH its backup installed
    s_loop, _, res = tx_loop(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        capacity=cap, max_rounds=10, rep=rc)
    assert bool(np.asarray(res.committed).all()), "loop must converge"
    assert int(np.asarray(res.round_abort_overflow)[0]) > 0
    checked = assert_replicas_byte_equal(
        s_loop, cfg, layout, rc, wk, np.ones((N, B, 1), bool))
    assert checked == N * B


def test_replica_config_validates():
    with pytest.raises(ValueError):
        repl.ReplicaConfig(4, -1)
    with pytest.raises(ValueError):
        repl.ReplicaConfig(4, 4)
    assert repl.ReplicaConfig(4, 3).n_copies == 4


# ---------------------------------------------------------------------------
# Failure injection: reads fail over to the first live replica
# ---------------------------------------------------------------------------
def test_kill_node_reads_fail_over(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 8
    rng = np.random.RandomState(21)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    wk = jnp.stack([klo, khi], -1)
    wv = value_for(klo + jnp.uint32(7))
    rc = repl.ReplicaConfig(N, 1)
    # populate THROUGH the replicated commit path: every record lands on
    # primary + backup
    state, _, res = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv, max_rounds=4, rep=rc)
    assert bool(np.asarray(res.committed).all())

    dead = 1
    alive = repl.kill_node(repl.all_alive(N), dead)
    # scorch the dead node's arena: if any fail-over read still touched it,
    # the values below could not come back intact
    state = dict(state, arena=state["arena"].at[dead].set(
        jnp.uint32(0xDEADBEEF)))

    flat_klo = klo.reshape(N, B)
    flat_khi = khi.reshape(N, B)
    out = repl.failover_lookup(t, state, flat_klo, flat_khi, cfg, layout,
                               rc, alive)
    assert bool(np.asarray(out["found"]).all()), \
        "every key must be served by a live replica"
    np.testing.assert_array_equal(
        np.asarray(out["value"]),
        np.asarray(wv.reshape(N, B, sl.VALUE_WORDS)))
    assert not np.asarray(out["dead_route"]).any()
    # keys homed on the dead node were rerouted to their ring successor
    home = np.asarray(ht.home_of(cfg, flat_klo, flat_khi)[0])
    served = np.asarray(out["node"])
    assert (served[home == dead] == (dead + 1) % N).all()
    assert (served[home != dead] == home[home != dead]).all()
    assert (np.asarray(out["version"]) % 2 == 0).all()

    # both copies dead -> the lane is parked and REPORTED, never served junk
    alive2 = repl.kill_node(alive, (dead + 1) % N)
    out2 = repl.failover_lookup(t, state, flat_klo, flat_khi, cfg, layout,
                                rc, alive2)
    dr = np.asarray(out2["dead_route"])
    np.testing.assert_array_equal(dr, home == dead)
    assert not np.asarray(out2["found"])[dr].any()


def test_failover_lookup_matches_hybrid_when_all_alive(cfg, layout):
    """With every node up, the failover path IS the ordinary hybrid lookup."""
    from repro.core import hybrid as hy
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(31)
    klo = jnp.asarray(rng.randint(0, 2**31, (N, 6)), jnp.uint32)
    khi = jnp.asarray(rng.randint(0, 2**31, (N, 6)), jnp.uint32)
    state = insert_keys(t, state, cfg, layout, klo, khi)
    rc = repl.ReplicaConfig(N, 1)
    out = repl.failover_lookup(t, state, klo, khi, cfg, layout, rc,
                               repl.all_alive(N))
    _, _, found, value, version, node, sidx, _, _ = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout)
    np.testing.assert_array_equal(np.asarray(out["found"]), np.asarray(found))
    np.testing.assert_array_equal(np.asarray(out["value"]), np.asarray(value))
    np.testing.assert_array_equal(np.asarray(out["node"]), np.asarray(node))
    np.testing.assert_array_equal(np.asarray(out["version"]),
                                  np.asarray(version))


# ---------------------------------------------------------------------------
# Ordered index under failure: kill a primary, serve from the backup tree
# ---------------------------------------------------------------------------
def _btree_replicated_cluster(f=1, n_per_node=6, seed=47):
    """A populated btree cluster whose every key was committed THROUGH the
    replicated scan-tx path (OP_BT_BACKUP fan-out on the commit round)."""
    from repro.core.datastructs import btree as bt
    from repro.core.txloop import scan_loop
    cfg = bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4)
    layout = bt.build_layout(cfg)
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(seed)
    wk = jnp.asarray(rng.randint(0, 2**32, (N, n_per_node, 1),
                                 dtype=np.uint32))
    wv = value_for(wk)
    state, _, res = scan_loop(
        t, state, cfg, layout, scan_lo=wk[..., 0], scan_hi=wk[..., 0],
        scan_enabled=jnp.zeros((N, n_per_node), bool), write_keys=wk,
        write_values=wv, max_rounds=10, rep=repl.ReplicaConfig(N, f))
    assert bool(np.asarray(res.committed).all())
    return t, state, cfg, layout, wk[..., 0], wv


def test_btree_primary_death_point_lookups_from_backup_tree():
    """Kill a primary at f=1: every point lookup fails over to the ring
    successor and is served from its full-range BACKUP tree (the RPC
    fallback resolves the foreign-partition key — correct, never fast)."""
    from repro.core import placement as pl
    from repro.core.datastructs import btree as bt
    t, state, cfg, layout, keys, wv = _btree_replicated_cluster()
    dead = 1
    alive = repl.kill_node(repl.all_alive(N), dead)
    # scorch the dead node: any read still touching it would come back junk
    state = dict(state, arena=state["arena"].at[dead].set(jnp.uint32(0xDEAD)))
    table = pl.table_from_replica(repl.ReplicaConfig(N, 1), alive)
    out = pl.failover_lookup(t, state, cfg, layout, table, keys,
                             jnp.zeros_like(keys), ds=bt)
    assert bool(np.asarray(out["found"]).all()), \
        "every key must be served by a live copy"
    np.testing.assert_array_equal(
        np.asarray(out["value"]),
        np.asarray(wv.reshape(N, -1, sl.VALUE_WORDS)))
    home = np.asarray(bt.home_of(cfg, keys))
    served = np.asarray(out["node"])
    assert (served[home == dead] == (dead + 1) % N).all(), \
        "dead-partition keys must be served by the ring successor"
    assert (served[home != dead] == home[home != dead]).all()
    assert not np.asarray(out["dead_route"]).any()


def test_btree_primary_death_scans_from_backup_tree():
    """Range scans over the dead partition are planned against the backup
    tree's OWN separator directory (refresh_backup_meta) and served by
    one-sided reads of its leaves; the survivors' primary fence chains stay
    fully intact."""
    from repro.core import onesided as osd
    from repro.core.datastructs import btree as bt
    from tests.test_btree import walk_leaves
    t, state, cfg, layout, keys, wv = _btree_replicated_cluster(seed=53)
    dead = 1
    backup = (dead + 1) % N
    state = dict(state, arena=state["arena"].at[dead].set(jnp.uint32(0xDEAD)))

    meta_b, stats = bt.refresh_backup_meta(t, state, cfg, layout)
    assert float(stats.round_trips) == 1.0, \
        "the backup directory refresh is ONE one-sided read round"
    nleaf = int(np.asarray(meta_b["nleaf"])[0, backup])
    assert nleaf >= 1

    # scan the dead node's whole partition out of the backup tree
    lo, hi = (int(np.asarray(x)) for x in bt.partition_bounds(cfg, dead))
    offs = jnp.asarray([np.asarray(bt.backup_leaf_offset(cfg, layout, i))
                        for i in range(nleaf)], jnp.uint32)
    dest = jnp.full((t.n_local, nleaf), backup, jnp.int32)
    buf, ovf, _ = osd.remote_read(
        t, state["arena"], dest,
        jnp.broadcast_to(offs[None], (t.n_local, nleaf)),
        length=cfg.leaf_words)
    assert not bool(ovf.any())
    p = bt.parse_leaf(cfg, buf[0])
    ks = np.asarray(p["keys"])
    live = np.asarray(p["live"])
    got = sorted(int(k) for k in ks[live] if lo <= int(k) <= hi)
    kflat = np.asarray(keys).reshape(-1)
    want = sorted(int(k) for k in kflat if lo <= int(k) <= hi)
    assert want, "the workload must land keys in the dead partition"
    assert set(want) <= set(got), \
        "the backup tree must serve every committed key of the dead range"

    # the failover touched nothing: every survivor's fence chain still holds
    for n in range(N):
        if n != dead:
            walk_leaves(state, cfg, layout, n)
