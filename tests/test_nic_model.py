"""Tests for the NIC connection-state subsystem (core/nic) and its threading
through the transport's wire accounting: the paper's Fig. 7 numbers must
emerge from the shared model, and every WireStats must carry the modeled
NIC-cache hit rate of the connection mode it ran under."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nic as qn
from repro.core import onesided as osd
from repro.core import slots as sl
from repro.core import txloop as txl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport, WireStats


# ---------------------------------------------------------------------------
# The model itself (paper Fig. 7 anchor points)
# ---------------------------------------------------------------------------
def test_rc_exclusive_rack_scale_stays_cached():
    """32 nodes / 10 threads: QP state fits the NIC cache (>= 99% hit)."""
    ct = qn.ConnTable(n_nodes=32, threads=10, mode=qn.RC_EXCLUSIVE)
    assert ct.conns_per_node == 2 * 32 * 10
    assert ct.cache_hit >= 0.99
    assert ct.penalty_us_per_op == pytest.approx(0.0, abs=1e-9)


def test_rc_exclusive_beyond_rack_drops_like_fig7():
    """96 nodes / 20 threads: the modeled throughput drops ~1.57x (the
    paper's Fig. 7 number), entirely from NIC-cache misses of QP state."""
    import sys
    import pathlib
    bench_dir = str(pathlib.Path(__file__).resolve().parents[1] / "benchmarks")
    sys.path.insert(0, bench_dir)
    try:
        from common import modeled_throughput_per_node
    finally:
        # don't leave benchmarks/ shadowing generic module names (common,
        # run, ...) for the rest of the pytest session
        sys.path.remove(bench_dir)

    def mops(m):
        ct = qn.ConnTable(n_nodes=m, threads=20, mode=qn.RC_EXCLUSIVE)
        return modeled_throughput_per_node(
            reads_per_op=1.0, rpcs_per_op=0.0, wire_bytes_per_op=140,
            lanes=32, nic=ct)

    ct96 = qn.ConnTable(n_nodes=96, threads=20, mode=qn.RC_EXCLUSIVE)
    assert ct96.cache_hit < 0.75           # 1.4 MiB of QP state vs 1 MiB cache
    drop = mops(32) / mops(96)
    assert 1.45 < drop < 1.70, drop        # paper: 1.57x


def test_dct_state_independent_of_node_count():
    for t in (1, 10, 20):
        sizes = {qn.ConnTable(n_nodes=m, threads=t, mode=qn.DCT).state_bytes
                 for m in (2, 32, 96, 128, 1024)}
        assert len(sizes) == 1             # O(1) in cluster size
        assert qn.ConnTable(n_nodes=2, threads=t, mode=qn.DCT).cache_hit == 1.0


def test_sharing_reduces_state_t_fold():
    ex = qn.ConnTable(n_nodes=96, threads=20, mode=qn.RC_EXCLUSIVE)
    sh = qn.ConnTable(n_nodes=96, threads=20, mode=qn.RC_SHARED)
    assert ex.conns_per_node == 20 * sh.conns_per_node
    assert sh.cache_hit == 1.0
    # sharing is NOT free: it pays a per-op synchronization cost that grows
    # with the number of sharers
    sh2 = qn.ConnTable(n_nodes=96, threads=2, mode=qn.RC_SHARED)
    assert sh.mode_cost_us > sh2.mode_cost_us > 0.0


def test_guideline_rc_wins_in_rack_sharing_wins_beyond():
    """The paper's §3.4 guideline, straight from the model."""
    def pen(m, mode):
        return qn.ConnTable(n_nodes=m, threads=20, mode=mode).penalty_us_per_op
    # inside the rack: exclusive RC is penalty-free, the others pay their cost
    assert pen(32, qn.RC_EXCLUSIVE) < pen(32, qn.RC_SHARED)
    assert pen(32, qn.RC_EXCLUSIVE) < pen(32, qn.DCT)
    # beyond the rack: exclusive RC pays PCIe fetches dwarfing both
    assert pen(96, qn.RC_EXCLUSIVE) > 5 * pen(96, qn.RC_SHARED)
    assert pen(96, qn.RC_EXCLUSIVE) > 5 * pen(96, qn.DCT)


def test_conn_table_validation():
    with pytest.raises(ValueError):
        qn.ConnTable(n_nodes=4, threads=2, mode="rc_bogus")
    with pytest.raises(ValueError):
        qn.ConnTable(n_nodes=0, threads=2)


# ---------------------------------------------------------------------------
# Threading through the wire accounting
# ---------------------------------------------------------------------------
def test_wirestats_carries_conn_table_and_stays_additive():
    t = SimTransport(2)
    arenas = jnp.arange(2 * 64, dtype=jnp.uint32).reshape(2, 64)
    dest = jnp.zeros((2, 4), jnp.int32)
    offs = jnp.zeros((2, 4), jnp.uint32)
    ct = qn.ConnTable(n_nodes=96, threads=20, mode=qn.RC_EXCLUSIVE)
    _, _, s1 = osd.remote_read(t, arenas, dest, offs, length=2, nic=ct)
    assert float(s1.nic_hit_rate) == pytest.approx(ct.cache_hit, abs=1e-6)
    assert float(s1.nic_penalty_us_per_op) == pytest.approx(
        ct.penalty_us_per_op, abs=1e-6)
    # no ConnTable -> perfect NIC (hit 1, penalty 0), including for zero()
    _, _, s0 = osd.remote_read(t, arenas, dest, offs, length=2)
    assert float(s0.nic_hit_rate) == 1.0
    assert float(s0.nic_penalty_us_per_op) == 0.0
    z = WireStats.zero()
    assert float(z.nic_hit_rate) == 1.0 and float(z.nic_penalty_us_per_op) == 0.0
    # additivity: summed stats report the ops-weighted mixture
    mix = s1 + s1 + s0
    w = 2 * float(s1.ops) * ct.cache_hit + float(s0.ops)
    assert float(mix.nic_hit_rate) == pytest.approx(
        w / float(mix.ops), abs=1e-6)


def test_tx_loop_reports_mode_hit_rate_without_changing_protocol():
    """Threading a ConnTable through the whole OCC loop changes ONLY the
    modeled NIC metrics — committed state, abort causes and wire counts are
    bit-identical (the model prices the transport, it does not alter it)."""
    n_nodes, lanes = 2, 6
    cfg = ht.HashTableConfig(n_nodes=n_nodes, n_buckets=32, bucket_width=1,
                             n_overflow=16, max_chain=4)
    layout = ht.build_layout(cfg)
    t = SimTransport(n_nodes)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(0)
    rk = jnp.asarray(rng.randint(0, 2**31, (n_nodes, lanes, 1, 2)), jnp.uint32)
    wk = jnp.asarray(rng.randint(0, 2**31, (n_nodes, lanes, 1, 2)), jnp.uint32)
    wv = jnp.ones((n_nodes, lanes, 1, sl.VALUE_WORDS), jnp.uint32)
    ct = qn.ConnTable(n_nodes=128, threads=20, mode=qn.RC_EXCLUSIVE)

    run = lambda nic: txl.tx_loop(
        t, state, cfg, layout, read_keys=rk, write_keys=wk, write_values=wv,
        max_rounds=2, nic=nic)
    st_a, _, res_a = run(None)
    st_b, _, res_b = run(ct)
    jax.tree.map(np.testing.assert_array_equal, st_a, st_b)
    np.testing.assert_array_equal(np.asarray(res_a.committed),
                                  np.asarray(res_b.committed))
    for f in ("round_trips", "messages", "ops", "req_bytes", "reply_bytes"):
        assert float(getattr(res_a.metrics.wire, f)) == \
            float(getattr(res_b.metrics.wire, f))
    assert float(res_a.metrics.wire.nic_penalty_us) == 0.0
    assert float(res_b.metrics.wire.nic_hit_rate) == pytest.approx(
        ct.cache_hit, abs=1e-4)
    assert float(res_b.metrics.wire.nic_penalty_us) > 0.0


def test_cost_model_fabric_with_nic():
    from repro.core import cost_model as cm
    ct = qn.ConnTable(n_nodes=96, threads=20, mode=qn.RC_EXCLUSIVE)
    fab = cm.Fabric().with_nic(ct)
    assert fab.nic_penalty_s == pytest.approx(ct.penalty_us_per_op * 1e-6)
    # a congested NIC shifts the one-sided-vs-RPC break-even: with enough
    # rounds on the one-sided side, penalties favour the single-round RPC
    base = cm.choose(1000.0, 1000.0, onesided_rounds=4.0, rpc_rounds=1.0)
    cong = cm.choose(1000.0, 1000.0, onesided_rounds=4.0, rpc_rounds=1.0,
                     fabric=fab)
    assert cong.onesided_time - cong.rpc_time > base.onesided_time - base.rpc_time
