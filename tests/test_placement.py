"""Placement subsystem: epoch-stamped routing tables, the region codec,
stale-route abort + refresh convergence, membership transitions with
re-replication, and transactional partition migration (no lost writes)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import placement as pl
from repro.core import replication as repl
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import wireproto as W
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.core.txloop import scan_loop, tx_loop
from repro.testing.workloads import value_for

N = 4


@pytest.fixture(scope="module")
def cfg():
    return ht.HashTableConfig(n_nodes=N, n_buckets=16, bucket_width=2,
                              n_overflow=64, max_chain=10)


@pytest.fixture(scope="module")
def layout(cfg):
    return ht.build_layout(cfg)


def keys_in_part(cfg, part, n, seed=0):
    """n distinct uint32 keys (key_hi = 0) hashing to partition `part`."""
    rng = np.random.RandomState(seed)
    out = []
    while len(out) < n:
        cand = rng.randint(0, 2**31, 4 * n).astype(np.uint32)
        p = np.asarray(ht.part_of(cfg, jnp.asarray(cand),
                                  jnp.zeros_like(jnp.asarray(cand))))
        out += [int(k) for k in cand[p == part]]
    return np.unique(np.asarray(out[:n], np.uint32))[:n]


def slots_of(state, cfg, layout, node):
    srg = layout["slots"]
    arena = np.asarray(state["arena"])
    return arena[node, srg.base:srg.base
                 + cfg.n_slots * sl.SLOT_WORDS].reshape(-1, sl.SLOT_WORDS)


def find_copy(state, cfg, layout, node, klo, khi=0):
    slots = slots_of(state, cfg, layout, node)
    m = (slots[:, sl.KEY_LO] == klo) & (slots[:, sl.KEY_HI] == khi)
    assert m.sum() <= 1, f"duplicate copies of one key on node {node}"
    return slots[m.argmax()] if m.any() else None


# ---------------------------------------------------------------------------
# The identity table IS the static partition math (bit-identity)
# ---------------------------------------------------------------------------
def test_identity_table_bit_identical_tx(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(7)
    B, Rd, Wr = 6, 2, 2
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, Rd + Wr)), jnp.uint32)
    khi = jnp.zeros_like(klo)
    rk = jnp.stack([klo[..., :Rd], khi[..., :Rd]], -1)
    wk = jnp.stack([klo[..., Rd:], khi[..., Rd:]], -1)
    wv = value_for(klo[..., Rd:])
    pcfg = pl.PlacementConfig(N, f=1)
    rep = repl.ReplicaConfig(N, 1)
    kw = dict(read_keys=rk, write_keys=wk, write_values=wv, max_rounds=4,
              rep=rep)
    s0, _, r0 = tx_loop(t, state, cfg, layout, **kw)
    s1, _, r1 = tx_loop(t, state, cfg, layout, ptable=pl.initial_table(pcfg),
                        pcfg=pcfg, **kw)
    np.testing.assert_array_equal(np.asarray(s0["arena"]),
                                  np.asarray(s1["arena"]))
    np.testing.assert_array_equal(np.asarray(r0.committed),
                                  np.asarray(r1.committed))
    assert float(r0.round_trips) == float(r1.round_trips), \
        "epoch-stable routing must not add a single exchange round"
    assert int(np.asarray(r1.round_abort_stale).sum()) == 0


def test_identity_table_bit_identical_scan():
    cfg = bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4)
    layout = bt.build_layout(cfg)
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    rng = np.random.RandomState(11)
    keys = jnp.asarray(rng.randint(0, 2**30, (N, 6)), jnp.uint32)
    h = bt.make_rpc_handler(cfg, layout)
    state, rep_, _, _ = R.rpc_call(
        t, state, bt.home_of(cfg, keys),
        bt.make_record(W.OP_BT_INSERT, keys, jnp.zeros_like(keys),
                       value=value_for(keys)), h)
    assert (np.asarray(rep_[..., 0]) == W.ST_OK).all()
    B = 6
    lo = jnp.asarray(rng.randint(0, 2**30, (N, B)), jnp.uint32)
    hi = lo + jnp.uint32(1 << 20)
    wk = jnp.asarray(rng.randint(0, 2**30, (N, B, 1)), jnp.uint32)
    pcfg = pl.PlacementConfig(N)
    kw = dict(scan_lo=lo, scan_hi=hi, write_keys=wk,
              write_values=value_for(wk), max_rounds=3)
    s0, _, r0 = scan_loop(t, state, cfg, layout, **kw)
    s1, _, r1 = scan_loop(t, state, cfg, layout,
                          ptable=pl.initial_table(pcfg), pcfg=pcfg, **kw)
    np.testing.assert_array_equal(np.asarray(s0["arena"]),
                                  np.asarray(s1["arena"]))
    np.testing.assert_array_equal(np.asarray(r0.committed),
                                  np.asarray(r1.committed))
    assert float(r0.round_trips) == float(r1.round_trips)
    assert int(np.asarray(r1.round_abort_stale).sum()) == 0


# ---------------------------------------------------------------------------
# Region codec + wire publication round-trip
# ---------------------------------------------------------------------------
def test_region_codec_roundtrip():
    pcfg = pl.PlacementConfig(N, f=1)
    table = pl.initial_table(pcfg)
    table = pl.kill_node(pcfg, table, 3)
    table = pl.PlacementTable(
        table.epoch, table.copies.at[2].set(jnp.asarray([1, 0, -1, -1],
                                                        jnp.int32)),
        table.alive)
    dec = pl.decode_region(pcfg, pl.region_image(pcfg, table))
    assert int(dec.epoch) == int(table.epoch) == 1
    np.testing.assert_array_equal(np.asarray(dec.copies),
                                  np.asarray(table.copies))
    np.testing.assert_array_equal(np.asarray(dec.alive),
                                  np.asarray(table.alive))


def test_install_then_refresh_round_trips_the_table(cfg, layout):
    """install_table broadcasts OP_PL_INSTALL records; refresh_table reads the
    published region back with ONE one-sided read and decodes the same
    table.  A disabled refresh issues zero wire."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N, f=1)
    table = pl.kill_node(pcfg, pl.initial_table(pcfg), 1)
    table, _ = pl.repair_plan(pcfg, table)
    h = ht.make_rpc_handler(cfg, layout)
    state, _ = pl.install_table(t, state, layout, pcfg, table, h)
    got, stats = pl.refresh_table(t, state, layout, pcfg,
                                  pl.initial_table(pcfg))
    assert int(got.epoch) == int(table.epoch)
    np.testing.assert_array_equal(np.asarray(got.copies),
                                  np.asarray(table.copies))
    np.testing.assert_array_equal(np.asarray(got.alive),
                                  np.asarray(table.alive))
    assert float(stats.round_trips) == 1.0, \
        "a table refresh is ONE one-sided read"
    _, s_off = pl.refresh_table(t, state, layout, pcfg, table,
                                enabled=jnp.asarray(False))
    assert float(s_off.ops) == 0.0 and float(s_off.round_trips) == 0.0, \
        "a gated-off refresh must cost zero wire"


def test_routing_queries_and_parking():
    pcfg = pl.PlacementConfig(N, f=1)
    table = pl.initial_table(pcfg)
    assert int(pl.owner_of(table, 2)) == 2
    np.testing.assert_array_equal(np.asarray(pl.copy_nodes(table, 1))[:2],
                                  [1, 2])
    table = pl.kill_node(pcfg, table, 1)
    # dead owner: writes park (-1), reads fail over to the live backup
    assert int(pl.owner_dest(table, 1)) == -1
    d, ok = pl.live_dest(table, 1)
    assert int(d) == 2 and bool(ok)
    # every copy dead: both park, and the lane reports unreachable
    table = pl.kill_node(pcfg, table, 2)
    d, ok = pl.live_dest(table, 1)
    assert int(d) == -1 and not bool(ok)


# ---------------------------------------------------------------------------
# Stale-route abort -> refresh -> converge
# ---------------------------------------------------------------------------
def test_stale_route_aborts_then_refresh_converges(cfg, layout):
    """A client whose cached table predates a migration routes lock-class ops
    to the OLD owner, gets ST_WRONG_EPOCH (cause stale_route, no partial
    state), refreshes its table on the retry round, and commits at the new
    owner — the separator-directory retry idiom applied to routing."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N)
    fresh = pl.PlacementTable(
        jnp.uint32(1),
        pl.initial_table(pcfg).copies.at[0, 0].set(2),
        jnp.ones((N,), bool))
    state = pl.install_local(state, layout, pcfg, fresh)

    B = 4
    wk0 = keys_in_part(cfg, 0, N * B, seed=3).reshape(N, B, 1)
    wk = jnp.stack([jnp.asarray(wk0, jnp.uint32),
                    jnp.zeros((N, B, 1), jnp.uint32)], -1)
    wv = value_for(wk[..., 0])
    stale = pl.initial_table(pcfg)           # epoch 0: still says owner 0
    state, _, res = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv, max_rounds=4, ptable=stale, pcfg=pcfg)
    r = np.asarray
    assert int(r(res.round_abort_stale)[0]) == N * B, \
        "round 0 must abort every lane with cause stale_route"
    assert int(r(res.round_abort_stale)[1:].sum()) == 0, \
        "one refresh must clear the staleness"
    assert bool(r(res.committed).all()), "retry must converge at the new owner"
    for k in wk0.reshape(-1):
        assert find_copy(state, cfg, layout, 2, k) is not None, \
            "committed writes must land at the NEW owner"
        assert find_copy(state, cfg, layout, 0, k) is None, \
            "the old owner must reject (and not install) stale-routed locks"


# ---------------------------------------------------------------------------
# Membership: kill -> repair_plan -> rereplicate restores f+1 copies
# ---------------------------------------------------------------------------
def test_kill_repair_rereplicate_restores_copies_hash(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N, f=1)
    rep = repl.ReplicaConfig(N, 1)
    table = pl.initial_table(pcfg)
    rng = np.random.RandomState(23)
    B = 6
    klo = jnp.asarray(rng.randint(0, 2**31, (N, B, 1)), jnp.uint32)
    wk = jnp.stack([klo, jnp.zeros_like(klo)], -1)
    wv = value_for(klo)
    state, _, res = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv, max_rounds=4, rep=rep, ptable=table,
        pcfg=pcfg)
    assert bool(np.asarray(res.committed).all())

    dead = 1
    table = pl.kill_node(pcfg, table, dead)
    table2, transfers = pl.repair_plan(pcfg, table)
    assert int(table2.epoch) == int(table.epoch) + 1
    cps = np.asarray(table2.copies)
    alive = np.asarray(table2.alive)
    for p in range(N):
        row = [c for c in cps[p] if c >= 0]
        assert len(row) == pcfg.n_copies and all(alive[c] for c in row), \
            "repair must refill every partition with live copies"
    assert cps[dead, 0] != dead, "the dead owner must be demoted"
    assert len(transfers) > 0

    # scorch the dead arena; nothing below may read it
    state = dict(state, arena=state["arena"].at[dead].set(jnp.uint32(0xDEAD)))
    state = pl.install_local(state, layout, pcfg, table2,
                             nodes=[n for n in range(N) if n != dead])
    state, stats = pl.rereplicate(t, state, cfg, layout, pcfg, transfers)
    assert float(stats.total_bytes) > 0.0

    # every committed key now has f+1 LIVE byte-equal copies per the table
    keep = [j for j in range(sl.SLOT_WORDS) if j != sl.NEXT_PTR]
    part = np.asarray(ht.part_of(cfg, klo[..., 0],
                                 jnp.zeros_like(klo[..., 0])))
    for k, p in zip(np.asarray(klo[..., 0]).reshape(-1), part.reshape(-1)):
        row = [int(c) for c in cps[p] if c >= 0]
        imgs = [find_copy(state, cfg, layout, c, k) for c in row]
        for c, img in zip(row, imgs):
            assert img is not None, \
                f"key {k} (part {p}) missing its copy on node {c}"
            np.testing.assert_array_equal(imgs[0][keep], img[keep])


def test_kill_repair_rereplicate_btree_logical():
    cfg = bt.BTreeConfig(n_nodes=N, n_leaves=32, leaf_width=4)
    layout = bt.build_layout(cfg)
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N, f=1)
    rep = repl.ReplicaConfig(N, 1)
    rng = np.random.RandomState(29)
    B = 6
    wk = jnp.asarray(rng.randint(0, 2**32, (N, B, 1), dtype=np.uint32))
    wv = value_for(wk)
    # populate THROUGH the replicated scan-tx commit path (write-only lanes:
    # a scan covering one's own write self-conflicts in validation)
    state, _, res = scan_loop(t, state, cfg, layout, scan_lo=wk[..., 0],
                              scan_hi=wk[..., 0],
                              scan_enabled=jnp.zeros((N, B), bool),
                              write_keys=wk, write_values=wv, max_rounds=10,
                              rep=rep)
    assert bool(np.asarray(res.committed).all())

    dead = 1
    table = pl.kill_node(pcfg, pl.initial_table(pcfg), dead)
    table2, transfers = pl.repair_plan(pcfg, table)
    state = pl.install_local(state, layout, pcfg, table2,
                             nodes=[n for n in range(N) if n != dead])
    state, stats = pl.rereplicate(t, state, cfg, layout, pcfg, transfers)
    assert float(stats.total_bytes) > 0.0

    # logical equality: every committed key is found with its value through
    # the repaired table (dead partition served by the promoted owner's
    # backup tree), and the NEW backup holds the dead partition's keys
    out = pl.failover_lookup(t, state, cfg, layout, table2, wk[..., 0],
                             jnp.zeros_like(wk[..., 0]), ds=bt)
    assert bool(np.asarray(out["found"]).all())
    np.testing.assert_array_equal(
        np.asarray(out["value"]),
        np.asarray(wv.reshape(N, B, sl.VALUE_WORDS)))
    cps = np.asarray(table2.copies)
    new_backup = int(cps[dead, 1])
    assert new_backup != dead and new_backup != int(cps[dead, 0])
    lo, hi = (int(np.asarray(x)) for x in bt.partition_bounds(cfg, dead))
    kflat = np.asarray(wk[..., 0]).reshape(-1)
    want = sorted(int(k) for k in kflat if lo <= int(k) <= hi)
    arena = np.asarray(state["arena"])[new_backup]
    bl = layout["bleaves"]
    leaves = arena[bl.base:bl.base + cfg.n_leaves * cfg.leaf_words].reshape(
        cfg.n_leaves, cfg.leaf_slots, sl.SLOT_WORDS)
    got = sorted(int(k) for k in leaves[:, 1:, sl.KEY_LO].reshape(-1)
                 if lo <= int(k) <= hi and k != 0xFFFFFFFF)
    assert set(want) <= set(got), \
        "re-replication must stream the dead partition to the new backup"


# ---------------------------------------------------------------------------
# Transactional migration: source-lock -> copy -> epoch flip
# ---------------------------------------------------------------------------
def test_migration_moves_partition_and_stale_clients_converge(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N)
    table = pl.initial_table(pcfg)
    state = pl.install_local(state, layout, pcfg, table)
    B = 4
    k0 = keys_in_part(cfg, 0, N * B, seed=41).reshape(N, B, 1)
    wk = jnp.stack([jnp.asarray(k0, jnp.uint32),
                    jnp.zeros((N, B, 1), jnp.uint32)], -1)
    wv = value_for(wk[..., 0])
    state, _, res = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv, max_rounds=4, ptable=table, pcfg=pcfg)
    assert bool(np.asarray(res.committed).all())

    table2, state, stats, ok = pl.migrate_partition(
        t, state, cfg, layout, pcfg, table, part=0, dst=2)
    assert ok and int(table2.epoch) == int(table.epoch) + 1
    assert int(pl.owner_of(table2, 0)) == 2
    # every committed record was copied and is served at the new owner
    out = pl.failover_lookup(t, state, cfg, layout, table2,
                             jnp.asarray(k0[..., 0], jnp.uint32),
                             jnp.zeros((N, B), jnp.uint32))
    assert bool(np.asarray(out["found"]).all())
    np.testing.assert_array_equal(np.asarray(out["value"]),
                                  np.asarray(wv.reshape(N, B, sl.VALUE_WORDS)))
    assert (np.asarray(out["node"]) == 2).all()
    # no dangling migration locks anywhere
    for n in range(N):
        assert (slots_of(state, cfg, layout, n)[:, sl.LOCK] == 0).all()

    # a stale client still converges: wrong-epoch abort, refresh, commit
    wv2 = value_for(wk[..., 0] + jnp.uint32(5))
    state, _, res2 = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv2, max_rounds=4, ptable=table,
        pcfg=pcfg)
    assert int(np.asarray(res2.round_abort_stale)[0]) == N * B
    assert bool(np.asarray(res2.committed).all())


def test_migration_aborts_cleanly_under_conflicting_lock(cfg, layout):
    """The no-lost-write guarantee: a migration racing an in-flight client
    lock fails its source-lock phase, releases everything it took, and leaves
    the table unchanged — it never copies a half-committed partition."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N)
    table = pl.initial_table(pcfg)
    state = pl.install_local(state, layout, pcfg, table)
    keys = keys_in_part(cfg, 0, 4, seed=53)
    h = ht.make_rpc_handler(cfg, layout)
    kj = jnp.tile(jnp.asarray(keys[None], jnp.uint32), (N, 1))
    only0 = jnp.zeros((N, 4), bool).at[0].set(True)
    state, rep_, _, _ = R.rpc_call(
        t, state, jnp.zeros((N, 4), jnp.int32),
        ht.make_record(W.OP_INSERT, kj, jnp.zeros_like(kj),
                       value=value_for(kj)), h, enabled=only0)
    assert (np.asarray(rep_[0, :, 0]) == W.ST_OK).all()

    # a client holds a lock on one key of the partition
    tag = jnp.uint32(0x7E570001)
    state, repl_, _, _ = R.rpc_call(
        t, state, jnp.zeros((N, 1), jnp.int32),
        ht.make_record(W.OP_LOCK, kj[:, :1], jnp.zeros((N, 1), jnp.uint32),
                       aux=jnp.full((N, 1), tag)),
        h, enabled=jnp.zeros((N, 1), bool).at[0].set(True))
    assert int(np.asarray(repl_[0, 0, 0])) == W.ST_OK
    lock_slot = np.asarray(repl_[0, 0, 1])

    t2, state, _, ok = pl.migrate_partition(t, state, cfg, layout, pcfg,
                                            table, part=0, dst=2)
    assert not ok, "migration must abort while a client lock is in flight"
    assert int(t2.epoch) == int(table.epoch), "an aborted migration flips nothing"
    locks = slots_of(state, cfg, layout, 0)[:, sl.LOCK]
    assert (locks == np.uint32(tag)).sum() == 1, \
        "the client's lock survives; every migration lock is released"

    # client unlocks; the retried migration goes through
    state, _, _, _ = R.rpc_call(
        t, state, jnp.zeros((N, 1), jnp.int32),
        ht.make_record(W.OP_ABORT_UNLOCK, jnp.full((N, 1), tag),
                       jnp.zeros((N, 1), jnp.uint32),
                       aux=jnp.broadcast_to(jnp.asarray(lock_slot), (N, 1))),
        h, enabled=jnp.zeros((N, 1), bool).at[0].set(True))
    t3, state, _, ok = pl.migrate_partition(t, state, cfg, layout, pcfg,
                                            table, part=0, dst=2)
    assert ok and int(pl.owner_of(t3, 0)) == 2


def test_migration_churn_loses_no_committed_write(cfg, layout):
    """Property-style churn: alternate commit batches with partition
    migrations (clients deliberately one epoch stale).  After every round the
    union of committed writes must be readable, with its latest value,
    through the CURRENT table."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N)
    table = pl.initial_table(pcfg)
    state = pl.install_local(state, layout, pcfg, table)
    rng = np.random.RandomState(67)
    committed = {}
    B = 4
    stale_view = table
    for rnd in range(3):
        klo = rng.randint(0, 2**31, (N, B, 1)).astype(np.uint32)
        wk = jnp.stack([jnp.asarray(klo), jnp.zeros((N, B, 1), jnp.uint32)],
                       -1)
        wv = value_for(jnp.asarray(klo) + jnp.uint32(rnd))
        state, _, res = tx_loop(
            t, state, cfg, layout,
            read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
            write_keys=wk, write_values=wv, max_rounds=5, ptable=stale_view,
            pcfg=pcfg)
        assert bool(np.asarray(res.committed).all())
        vals = np.asarray(wv).reshape(-1, sl.VALUE_WORDS)
        for i, k in enumerate(klo.reshape(-1)):
            committed[int(k)] = vals[i]

        part = int(rng.randint(0, N))
        dst = int(rng.randint(0, N))
        table2, state, _, ok = pl.migrate_partition(
            t, state, cfg, layout, pcfg, table, part=part, dst=dst)
        assert ok, "no client lock is in flight between batches"
        stale_view = table          # clients lag one epoch behind
        table = table2

        ks = np.asarray(sorted(committed), np.uint32).reshape(1, -1)
        ks = np.tile(ks, (N, 1))
        out = pl.failover_lookup(t, state, cfg, layout, table,
                                 jnp.asarray(ks), jnp.zeros_like(
                                     jnp.asarray(ks)))
        assert bool(np.asarray(out["found"]).all()), \
            f"round {rnd}: a committed key vanished after migration"
        got = np.asarray(out["value"])[0]
        want = np.stack([committed[int(k)] for k in ks[0]])
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Dead-owner parking: writes park and are REPORTED, never misrouted
# ---------------------------------------------------------------------------
def test_dead_owner_parks_writes_until_repair(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    pcfg = pl.PlacementConfig(N, f=1)
    table = pl.kill_node(pcfg, pl.initial_table(pcfg), 1)
    state = pl.install_local(state, layout, pcfg, table)
    B = 4
    k1 = keys_in_part(cfg, 1, B, seed=71)        # owned by the dead node
    k2 = keys_in_part(cfg, 2, B, seed=72)        # healthy partition
    klo = jnp.asarray(np.stack([np.tile(k1, (N, 1)),
                                np.tile(k2, (N, 1))], axis=-1), jnp.uint32)
    wk = jnp.stack([klo, jnp.zeros_like(klo)], -1)        # (N, B, 2, 2)
    wv = value_for(klo)
    state, _, res = tx_loop(
        t, state, cfg, layout, read_keys=jnp.zeros((N, B, 0, 2), jnp.uint32),
        write_keys=wk, write_values=wv, max_rounds=3, ptable=table, pcfg=pcfg,
        rep=repl.ReplicaConfig(N, 1))
    r = np.asarray
    assert not r(res.committed).any(), \
        "a lane touching a dead-owner partition must not commit"
    assert int(r(res.round_abort_overflow).sum()) > 0, \
        "parked lanes surface as overflow (dropped), never silent"
    # nothing was silently written to the backup
    for k in k1:
        assert find_copy(state, cfg, layout, 2, int(k)) is None


# ---------------------------------------------------------------------------
# Membership transition bookkeeping
# ---------------------------------------------------------------------------
def test_join_leave_kill_bump_epoch_and_drain_plan():
    pcfg = pl.PlacementConfig(N, f=1)
    table = pl.initial_table(pcfg)
    t1 = pl.kill_node(pcfg, table, 3)
    t2 = pl.join_node(pcfg, t1, 3)
    t3 = pl.leave_node(pcfg, t2, 0)
    assert [int(x.epoch) for x in (t1, t2, t3)] == [1, 2, 3]
    assert bool(t2.alive[3]) and not bool(t3.alive[0])
    plan = pl.drain_plan(pcfg, t2, 0)
    assert len(plan) == 1 and plan[0][0] == 0
    p, dst = plan[0]
    assert dst not in set(int(c) for c in np.asarray(t2.copies)[p]), \
        "the drain destination must not already hold a copy"


def test_placement_config_validates():
    with pytest.raises(ValueError):
        pl.PlacementConfig(4, f=-1)
    with pytest.raises(ValueError):
        pl.PlacementConfig(4, f=4)
    with pytest.raises(ValueError):
        pl.PlacementConfig(8, f=4)        # f + 1 > MAX_COPIES
    assert pl.PlacementConfig(4, f=3).n_copies == 4
