"""Property tests for core/regions: the paged (MTT-walk) and flat
(physical-segment) addressing modes must be observationally identical, and
the region bounds check (the MPT's protection role) must reject out-of-region
access in BOTH modes.

Runs under real hypothesis when installed; otherwise falls back to the
fixed-sample stub in repro.testing (same idiom as test_property_storm)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import regions as rg

PAGE_WORDS = 16   # small pages so offsets cross page boundaries often


def _setup(total_words, seed, permute_pages=False):
    """An arena filled with distinct words + paged/flat modes.  When
    `permute_pages`, the page table is a random permutation and the paged
    arena's physical pages are laid out to match, so logical reads through
    the two modes must still agree (proves the translation is honoured,
    not a no-op)."""
    rng = np.random.RandomState(seed)
    flat = jnp.asarray(rng.randint(0, 2**31, total_words), jnp.uint32)
    mode = rg.AddressMode(kind="paged", page_words=PAGE_WORDS)
    pt = mode.make_page_table(total_words)
    paged_arena = flat
    if permute_pages:
        perm = rng.permutation(len(pt))
        pt = jnp.asarray(perm, jnp.uint32)
        # physical page perm[i] must hold logical page i
        phys = np.zeros(len(pt) * PAGE_WORDS, np.uint32)
        for logical, physical in enumerate(perm):
            phys[physical * PAGE_WORDS:(physical + 1) * PAGE_WORDS] = \
                np.asarray(flat)[logical * PAGE_WORDS:(logical + 1) * PAGE_WORDS]
        paged_arena = jnp.asarray(phys)
    return flat, paged_arena, mode, pt


@settings(max_examples=16, deadline=None)
@given(
    n_offsets=st.integers(1, 12),
    length=st.integers(1, 8),
    permute=st.booleans(),
    seed=st.integers(0, 1000),
)
def test_paged_flat_reads_identical(n_offsets, length, permute, seed):
    total = 16 * PAGE_WORDS
    flat, paged_arena, mode, pt = _setup(total, seed, permute_pages=permute)
    rng = np.random.RandomState(seed + 1)
    offs = jnp.asarray(rng.randint(0, total - length + 1, n_offsets), jnp.uint32)
    out_flat = rg.arena_read(flat, offs, length)
    out_paged = rg.arena_read(paged_arena, offs, length, mode=mode, page_table=pt)
    np.testing.assert_array_equal(np.asarray(out_flat), np.asarray(out_paged))


@settings(max_examples=16, deadline=None)
@given(
    n_offsets=st.integers(1, 12),
    length=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
def test_paged_flat_writes_identical(n_offsets, length, seed):
    total = 16 * PAGE_WORDS
    rng = np.random.RandomState(seed + 2)
    base = jnp.asarray(rng.randint(0, 2**31, total), jnp.uint32)
    mode = rg.AddressMode(kind="paged", page_words=PAGE_WORDS)
    pt = mode.make_page_table(total)   # identity: same physical layout
    # non-overlapping writes (each offset its own length-aligned stripe) so
    # both modes see the same final state regardless of scatter order
    starts = rng.choice(total // length, size=min(n_offsets, total // length),
                        replace=False) * length
    offs = jnp.asarray(starts, jnp.uint32)
    vals = jnp.asarray(rng.randint(0, 2**31, (len(starts), length)), jnp.uint32)
    out_flat = rg.arena_write(base, offs, vals)
    out_paged = rg.arena_write(base, offs, vals, mode=mode, page_table=pt)
    np.testing.assert_array_equal(np.asarray(out_flat), np.asarray(out_paged))
    # and the writes actually landed
    got = rg.arena_read(out_flat, offs, length)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(vals))


@settings(max_examples=16, deadline=None)
@given(
    length=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
def test_out_of_region_rejected_both_modes(length, seed):
    """Accesses outside the registered region are rejected identically in
    flat and paged modes: reads come back zeros, writes leave the arena
    untouched — never a leak into the neighbouring region."""
    total = 16 * PAGE_WORDS
    table = rg.RegionTable()
    lo = table.register("lo", 4 * PAGE_WORDS)
    hi = table.register("hi", 12 * PAGE_WORDS)
    assert table.total_words == total and hi.base == lo.end
    flat, paged_arena, mode, pt = _setup(total, seed)
    rng = np.random.RandomState(seed + 3)
    inside = rng.randint(lo.base, lo.end - length + 1, 4)
    straddle = np.asarray([lo.end - min(length - 1, 1), lo.end - 1])
    # huge offsets whose uint32 `off + length` wraps around to a small value
    # must NOT sneak past the bounds check (the MPT is not fooled by wrap)
    wrap = np.asarray([2**32 - 1, 2**32 - max(length - 1, 1)], np.int64)
    outside = rng.randint(lo.end, total - length + 1, 4)
    offs = jnp.asarray(np.concatenate([inside, straddle, outside, wrap]),
                       jnp.uint32)
    ok = np.asarray(rg.in_region(lo, offs, length))
    assert ok[:4].all() and not ok[6:].any()
    if length > 1:
        assert not ok[4:6].any()     # straddling the boundary is rejected

    for arena, kw in ((flat, {}),
                      (paged_arena, dict(mode=mode, page_table=pt))):
        out = np.asarray(rg.arena_read(arena, offs, length, region=lo, **kw))
        # rejected lanes read zeros; accepted lanes read real data
        assert (out[~ok] == 0).all()
        np.testing.assert_array_equal(
            out[ok], np.asarray(rg.arena_read(arena, offs[ok], length, **kw)))

        vals = jnp.asarray(rng.randint(1, 2**31, (len(offs), length)), jnp.uint32)
        new = np.asarray(rg.arena_write(arena, offs, vals, region=lo, **kw))
        # out-of-region words are untouched (modulo in-region lanes' writes)
        touched = np.zeros(total, bool)
        for o in np.asarray(offs)[ok]:
            idx = np.arange(o, o + length)
            if kw:
                idx = np.asarray(mode.translate(pt, jnp.asarray(idx, jnp.uint32)))
            touched[idx] = True
        np.testing.assert_array_equal(new[~touched], np.asarray(arena)[~touched])
