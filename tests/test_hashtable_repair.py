"""Regressions for the hash-table repair satellites: chain-preserving slot
reuse, deleted-slot reclamation (bump allocator no longer grows forever),
exact-tag unlock ownership, and honest non-positive send-queue capacities."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import onesided as osd
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport


def one_node_table(n_overflow=8, bucket_width=1, max_chain=12):
    cfg = ht.HashTableConfig(n_nodes=1, n_buckets=1,
                             bucket_width=bucket_width,
                             n_overflow=n_overflow, max_chain=max_chain)
    layout = ht.build_layout(cfg)
    return cfg, layout, SimTransport(1), ht.init_cluster_state(cfg)


def call(t, state, h, op, keys, aux=None, values=None):
    klo = jnp.asarray([keys], jnp.uint32)
    khi = jnp.zeros_like(klo)
    node = jnp.zeros(klo.shape, jnp.int32)
    aux = None if aux is None else jnp.asarray([aux], jnp.uint32)
    values = None if values is None else jnp.asarray([values], jnp.uint32)
    recs = ht.make_record(op, klo, khi, aux=aux, value=values)
    state, rep, _, _ = R.rpc_call(t, state, node, recs, h)
    return state, np.asarray(rep[0])


def vals_for(keys):
    return np.asarray(
        sl._mix32(jnp.asarray(keys, jnp.uint32)[:, None]
                  + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)))


# ---------------------------------------------------------------------------
# Satellite: a fresh insert into a freed in-bucket slot must preserve the
# slot's next_ptr — severing it orphans every key on the overflow chain.
# ---------------------------------------------------------------------------
def test_reinsert_into_freed_bucket_slot_keeps_chain():
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    # one bucket of width 1: key 10 lands in the bucket slot, 20/30 chain
    state, rep = call(t, state, h, R.OP_INSERT, [10, 20, 30],
                      values=vals_for([10, 20, 30]))
    assert (rep[:, 0] == R.ST_OK).all()
    # delete the chain ANCHOR (in-bucket slot), then insert a fresh key —
    # which reuses that freed slot
    state, rep = call(t, state, h, R.OP_DELETE, [10])
    assert (rep[:, 0] == R.ST_OK).all()
    state, rep = call(t, state, h, R.OP_INSERT, [40], values=vals_for([40]))
    assert (rep[:, 0] == R.ST_OK).all()
    # every chained key must still round-trip (the old code wrote NULL_PTR
    # into the reused slot and orphaned 20 and 30)
    state, rep = call(t, state, h, R.OP_LOOKUP, [40, 20, 30])
    assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]
    np.testing.assert_array_equal(rep[:, 3:], vals_for([40, 20, 30]))


def test_reinsert_into_freed_chain_slot_keeps_suffix():
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    state, rep = call(t, state, h, R.OP_INSERT, [10, 20, 30],
                      values=vals_for([10, 20, 30]))
    assert (rep[:, 0] == R.ST_OK).all()
    # delete the MIDDLE chain node; reuse must keep its link to 30
    state, rep = call(t, state, h, R.OP_DELETE, [20])
    assert (rep[:, 0] == R.ST_OK).all()
    state, rep = call(t, state, h, R.OP_INSERT, [50], values=vals_for([50]))
    assert (rep[:, 0] == R.ST_OK).all()
    state, rep = call(t, state, h, R.OP_LOOKUP, [10, 50, 30])
    assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]


def test_lock_insert_placeholder_preserves_chain():
    """The lock-insert placeholder takes the same reuse path as OP_INSERT:
    locking a NEW key into a freed anchor slot must not sever the chain,
    and aborting it must leave the chain intact."""
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    state, rep = call(t, state, h, R.OP_INSERT, [10, 20, 30],
                      values=vals_for([10, 20, 30]))
    state, rep = call(t, state, h, R.OP_DELETE, [10])
    state, rep = call(t, state, h, R.OP_LOCK, [60], aux=[7])
    assert (rep[:, 0] == R.ST_OK).all()
    slot_idx = rep[0, 1]
    state, rep = call(t, state, h, R.OP_LOOKUP, [20, 30])
    assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]
    # roll the placeholder back (tag 7) and re-check the chain
    state, rep = call(t, state, h, R.OP_ABORT_UNLOCK, [7], aux=[slot_idx])
    assert (rep[:, 0] == R.ST_OK).all()
    state, rep = call(t, state, h, R.OP_LOOKUP, [20, 30])
    assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]


# ---------------------------------------------------------------------------
# Satellite: deleted slots are reclaimed — churn at fixed occupancy must
# never exhaust the overflow allocator.
# ---------------------------------------------------------------------------
@settings(max_examples=2, deadline=None)
@given(seed=st.sampled_from([3, 11]), width=st.sampled_from([1, 2]))
def test_churn_at_fixed_occupancy_never_no_space(seed, width):
    n_overflow = 5
    cfg, layout, t, state = one_node_table(n_overflow=n_overflow,
                                           bucket_width=width,
                                           max_chain=n_overflow + 4)
    h = ht.make_rpc_handler(cfg, layout)
    occupancy = width + n_overflow  # table completely full
    rng = np.random.RandomState(seed)
    keys = list(range(100, 100 + occupancy))
    state, rep = call(t, state, h, R.OP_INSERT, keys, values=vals_for(keys))
    assert (rep[:, 0] == R.ST_OK).all()
    next_key = 1000
    for _ in range(occupancy + 3):
        victim = keys.pop(rng.randint(len(keys)))
        state, rep = call(t, state, h, R.OP_DELETE, [victim])
        assert (rep[:, 0] == R.ST_OK).all()
        state, rep = call(t, state, h, R.OP_INSERT, [next_key],
                          values=vals_for([next_key]))
        # the old bump-only allocator hits ST_NO_SPACE on the first iteration
        # (the table started full); reclamation must always find the slot
        assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]
        keys.append(next_key)
        next_key += 1
    state, rep = call(t, state, h, R.OP_LOOKUP, keys)
    assert (rep[:, 0] == R.ST_OK).all(), rep[:, 0]
    np.testing.assert_array_equal(rep[:, 3:], vals_for(keys))


def test_reused_slot_version_stays_monotone():
    """Reuse must not reset the slot version: a delete -> re-insert of the
    SAME key must present a version different from the pre-delete one, or a
    concurrent validator could ABA past the change."""
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    state, rep = call(t, state, h, R.OP_INSERT, [10], values=vals_for([10]))
    state, rep = call(t, state, h, R.OP_LOOKUP, [10])
    v0 = int(rep[0, 2])
    state, _ = call(t, state, h, R.OP_DELETE, [10])
    state, rep = call(t, state, h, R.OP_INSERT, [10], values=vals_for([10]))
    state, rep = call(t, state, h, R.OP_LOOKUP, [10])
    v1 = int(rep[0, 2])
    assert v1 != v0 and v1 % 2 == 0, (v0, v1)


# ---------------------------------------------------------------------------
# Satellite: COMMIT/ABORT_UNLOCK must verify the exact lock tag.
# ---------------------------------------------------------------------------
def test_unlock_requires_exact_tag():
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    state, rep = call(t, state, h, R.OP_INSERT, [10], values=vals_for([10]))
    state, rep = call(t, state, h, R.OP_LOCK, [10], aux=[77])
    assert (rep[:, 0] == R.ST_OK).all()
    slot_idx = rep[0, 1]
    # a misrouted/retried unlock carrying another lane's tag must NOT release
    for op in (R.OP_ABORT_UNLOCK, R.OP_COMMIT_UNLOCK):
        state, rep = call(t, state, h, op, [88], aux=[slot_idx],
                          values=vals_for([10]))
        assert (rep[:, 0] == R.ST_LOCK_FAIL).all(), rep[:, 0]
    # the lock is still held: a second locker still loses
    state, rep = call(t, state, h, R.OP_LOCK, [10], aux=[99])
    assert (rep[:, 0] == R.ST_LOCK_FAIL).all()
    # the true owner releases fine
    state, rep = call(t, state, h, R.OP_ABORT_UNLOCK, [77], aux=[slot_idx])
    assert (rep[:, 0] == R.ST_OK).all()
    state, rep = call(t, state, h, R.OP_LOCK, [10], aux=[99])
    assert (rep[:, 0] == R.ST_OK).all()


# ---------------------------------------------------------------------------
# Satellite: capacity=0 back-pressures EVERYTHING (never "unbounded");
# negative capacities are rejected loudly.
# ---------------------------------------------------------------------------
def test_capacity_zero_backpressures_everything():
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    klo = jnp.asarray([[1, 2, 3]], jnp.uint32)
    khi = jnp.zeros_like(klo)
    node = jnp.zeros(klo.shape, jnp.int32)
    recs = ht.make_record(R.OP_INSERT, klo, khi, value=vals_for([1, 2, 3])[None])
    state2, rep, ovf, stats = R.rpc_call(t, state, node, recs, h, capacity=0)
    assert bool(np.asarray(ovf).all())
    np.testing.assert_array_equal(np.asarray(rep[..., 0]), R.ST_DROPPED)
    assert float(stats.ops) == 0.0 and float(stats.round_trips) == 0.0
    # nothing was delivered: the arena is untouched
    np.testing.assert_array_equal(np.asarray(state2["arena"]),
                                  np.asarray(state["arena"]))

    offs = jnp.zeros((1, 3), jnp.uint32)
    data, ovf, _ = osd.remote_read(t, state["arena"], node, offs, length=4,
                                   capacity=0)
    assert bool(np.asarray(ovf).all()) and not np.asarray(data).any()
    arenas, ovf, _ = osd.remote_write(t, state["arena"], node, offs,
                                      jnp.ones((1, 3, 4), jnp.uint32),
                                      capacity=0)
    assert bool(np.asarray(ovf).all())
    np.testing.assert_array_equal(np.asarray(arenas),
                                  np.asarray(state["arena"]))


def test_negative_capacity_rejected():
    cfg, layout, t, state = one_node_table()
    h = ht.make_rpc_handler(cfg, layout)
    klo = jnp.asarray([[1]], jnp.uint32)
    khi = jnp.zeros_like(klo)
    node = jnp.zeros(klo.shape, jnp.int32)
    recs = ht.make_record(R.OP_LOOKUP, klo, khi)
    offs = jnp.zeros((1, 1), jnp.uint32)
    with pytest.raises(ValueError):
        R.rpc_call(t, state, node, recs, h, capacity=-1)
    with pytest.raises(ValueError):
        osd.remote_read(t, state["arena"], node, offs, length=4, capacity=-1)
    with pytest.raises(ValueError):
        osd.remote_write(t, state["arena"], node, offs,
                         jnp.ones((1, 1, 4), jnp.uint32), capacity=-2)
