"""Storm dataplane behaviour tests: slots, regions, transport routing,
one-sided ops, RPC handlers, hybrid lookups, OCC transactions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import regions as rg
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import onesided as osd
from repro.core import hybrid as hy
from repro.core import tx as txm
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport, route_by_dest, pick_replies

N = 4  # simulated nodes


@pytest.fixture(scope="module")
def cfg():
    return ht.HashTableConfig(n_nodes=N, n_buckets=64, bucket_width=2,
                              n_overflow=64, max_chain=6)


@pytest.fixture(scope="module")
def layout(cfg):
    return ht.build_layout(cfg)


def make_keys(n, seed=0):
    rng = np.random.RandomState(seed)
    lo = rng.randint(0, 2**31, size=n).astype(np.uint32)
    hi = rng.randint(0, 2**31, size=n).astype(np.uint32)
    return jnp.asarray(lo), jnp.asarray(hi)


def value_for(key_lo):
    i = jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)
    return sl._mix32(key_lo[..., None] + i)


# ---------------------------------------------------------------------------
def test_slot_roundtrip():
    val = jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32)
    s = sl.pack_slot(7, 9, 4, 0, sl.NULL_PTR, val)
    assert int(sl.slot_key_lo(s)) == 7
    assert int(sl.slot_version(s)) == 4
    assert bool(sl.slot_matches(s, jnp.uint32(7), jnp.uint32(9)))
    assert not bool(sl.slot_matches(s, jnp.uint32(8), jnp.uint32(9)))
    s_locked = s.at[sl.LOCK].set(3)
    assert not bool(sl.slot_matches(s_locked, jnp.uint32(7), jnp.uint32(9)))
    s_odd = s.at[sl.VERSION].set(5)
    assert not bool(sl.slot_matches(s_odd, jnp.uint32(7), jnp.uint32(9)))


def test_region_paged_translation():
    mode = rg.AddressMode(kind="paged", page_words=8)
    table = mode.make_page_table(64)
    # permute pages and check translation is honoured
    perm = jnp.asarray(np.random.RandomState(0).permutation(8), jnp.uint32)
    arena = jnp.arange(64, dtype=jnp.uint32)
    # physical arena laid out so that logical word i lives at perm-page
    offs = jnp.arange(64, dtype=jnp.uint32)
    phys = mode.translate(perm, offs)
    assert phys.shape == offs.shape
    np.testing.assert_array_equal(
        np.asarray(phys), np.asarray(perm)[np.arange(64) // 8] * 8 + np.arange(64) % 8)


def test_route_by_dest_and_replies():
    B, n_dst, cap = 16, 4, 16
    rng = np.random.RandomState(1)
    dest = jnp.asarray(rng.randint(0, n_dst, B), jnp.int32)
    payload = jnp.asarray(rng.randint(0, 100, (B, 3)), jnp.uint32)
    buf, mask, pos, ovf = route_by_dest(dest, payload, n_dst, cap)
    assert not bool(ovf.any())
    assert int(mask.sum()) == B
    # echo replies: reply = payload, delivered back through pick
    out = pick_replies(buf, dest, pos, ovf)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(payload))


def test_route_overflow():
    B, n_dst, cap = 8, 2, 2
    dest = jnp.zeros((B,), jnp.int32)  # everyone to node 0, capacity 2
    payload = jnp.ones((B, 1), jnp.uint32)
    buf, mask, pos, ovf = route_by_dest(dest, payload, n_dst, cap)
    assert int(ovf.sum()) == B - cap
    assert int(mask.sum()) == cap


def test_one_sided_read_write(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    arenas = state["arena"]
    B = 8
    rng = np.random.RandomState(2)
    dest = jnp.asarray(rng.randint(0, N, (N, B)), jnp.int32)
    # write distinct patterns at distinct slot offsets, then read them back
    slot_ids = jnp.asarray(rng.choice(cfg.n_slots, (N, B), replace=False), jnp.uint32)
    offs = ht.slot_idx_offset(layout, slot_ids)
    vals = jnp.asarray(rng.randint(0, 2**31, (N, B, 4)), jnp.uint32)
    arenas, ovf, s = osd.remote_write(t, arenas, dest, offs, vals)
    assert not bool(ovf.any())
    data, ovf2, s2 = osd.remote_read(t, arenas, dest, offs, length=4)
    assert not bool(ovf2.any())
    np.testing.assert_array_equal(np.asarray(data), np.asarray(vals))
    assert float(s2.round_trips) == 1.0


def test_insert_then_lookup_rpc_only(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 32
    klo, khi = make_keys(N * B, seed=3)
    klo, khi = klo.reshape(N, B), khi.reshape(N, B)
    vals = value_for(klo)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    recs = ht.make_record(R.OP_INSERT, klo, khi, value=vals)
    h = ht.make_rpc_handler(cfg, layout)
    state, rep, ovf, _ = R.rpc_call(t, state, node, recs, h)
    assert not bool(ovf.any())
    np.testing.assert_array_equal(np.asarray(rep[..., 0]), R.ST_OK)

    # RPC-only lookup (serial handler)
    recs2 = ht.make_record(R.OP_LOOKUP, klo, khi)
    state, rep2, _, _ = R.rpc_call(t, state, node, recs2, h)
    np.testing.assert_array_equal(np.asarray(rep2[..., 0]), R.ST_OK)
    np.testing.assert_array_equal(np.asarray(rep2[..., 3:]), np.asarray(vals))

    # vectorized read-only handler agrees
    hv = ht.make_lookup_handler_vector(cfg, layout)
    state, rep3, _, _ = R.rpc_call(t, state, node, recs2, hv)
    np.testing.assert_array_equal(np.asarray(rep3[..., 0]), R.ST_OK)
    np.testing.assert_array_equal(np.asarray(rep3[..., 3:]), np.asarray(vals))

    # missing keys are NOT_FOUND
    mlo, mhi = make_keys(N * B, seed=99)
    mlo, mhi = mlo.reshape(N, B), mhi.reshape(N, B)
    mnode, _, _ = ht.lookup_start(cfg, layout, mlo, mhi)
    recsm = ht.make_record(R.OP_LOOKUP, mlo, mhi)
    state, repm, _, _ = R.rpc_call(t, state, mnode, recsm, h)
    np.testing.assert_array_equal(np.asarray(repm[..., 0]), R.ST_NOT_FOUND)


def test_hybrid_lookup_one_two_sided(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 48
    klo, khi = make_keys(N * B, seed=4)
    klo, khi = klo.reshape(N, B), khi.reshape(N, B)
    vals = value_for(klo)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    h = ht.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)

    state, cache, found, value, ver, onode, sidx, _, m = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=True)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(value), np.asarray(vals))
    # with 128 keys in 64*2-slot buckets most lookups succeed one-sided;
    # chained items fall back to RPC — both paths must agree
    assert float(m.onesided_success) + 0 >= 0
    assert float(m.onesided_success) + float(m.rpc_fallback) >= m.total


def test_hybrid_lookup_rpc_only_matches(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 16
    klo, khi = make_keys(N * B, seed=5)
    klo, khi = klo.reshape(N, B), khi.reshape(N, B)
    vals = value_for(klo)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    h = ht.make_rpc_handler(cfg, layout)
    state, _, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    s1, _, f1, v1, *_ = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=True)
    s2, _, f2, v2, *_ = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=False)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_overflow_chain_walk():
    # tiny table: force every key into one bucket -> chains exercise RPC path
    cfg = ht.HashTableConfig(n_nodes=1, n_buckets=1, bucket_width=1,
                             n_overflow=32, max_chain=20)
    layout = ht.build_layout(cfg)
    t = SimTransport(1)
    state = ht.init_cluster_state(cfg)
    B = 12
    klo, khi = make_keys(B, seed=6)
    klo, khi = klo.reshape(1, B), khi.reshape(1, B)
    vals = value_for(klo)
    node = jnp.zeros((1, B), jnp.int32)
    h = ht.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    # all but one key lives in the chain -> hybrid must still find all
    state, _, found, value, _, _, _, _, m = hy.hybrid_lookup(
        t, state, klo, khi, cfg, layout, use_onesided=True)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(value), np.asarray(vals))
    assert float(m.rpc_fallback) >= B - 1  # chained keys needed the RPC


def test_delete_and_update(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 8
    klo, khi = make_keys(N * B, seed=7)
    klo, khi = klo.reshape(N, B), khi.reshape(N, B)
    vals = value_for(klo)
    node, _, _ = ht.lookup_start(cfg, layout, klo, khi)
    h = ht.make_rpc_handler(cfg, layout)
    state, _, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    # update
    vals2 = value_for(klo + jnp.uint32(1))
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_UPDATE, klo, khi, value=vals2), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_LOOKUP, klo, khi), h)
    np.testing.assert_array_equal(np.asarray(rep[..., 3:]), np.asarray(vals2))
    # delete then miss
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_DELETE, klo, khi), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_LOOKUP, klo, khi), h)
    np.testing.assert_array_equal(np.asarray(rep[..., 0]), R.ST_NOT_FOUND)


def test_transactions_commit_and_isolation(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B, Rd, Wr = 8, 2, 1
    klo, khi = make_keys(N * (B * (Rd + Wr)), seed=8)
    klo = klo.reshape(N, B, Rd + Wr)
    khi = khi.reshape(N, B, Rd + Wr)
    vals = value_for(klo)
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout,
                                 klo.reshape(N, -1), khi.reshape(N, -1))
    state, rep, _, _ = R.rpc_call(
        t, state, node,
        ht.make_record(R.OP_INSERT, klo.reshape(N, -1), khi.reshape(N, -1),
                       value=vals.reshape(N, -1, sl.VALUE_WORDS)), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)

    read_keys = jnp.stack([klo[..., :Rd], khi[..., :Rd]], axis=-1)
    write_keys = jnp.stack([klo[..., Rd:], khi[..., Rd:]], axis=-1)
    new_vals = value_for(klo[..., Rd:] + jnp.uint32(42))
    state, _, res = txm.run_transactions(
        t, state, cfg, layout, read_keys=read_keys, write_keys=write_keys,
        write_values=new_vals)
    # disjoint keys -> every transaction commits
    assert bool(res.committed.all()), np.asarray(res.committed)
    assert bool(res.read_found.all())
    np.testing.assert_array_equal(
        np.asarray(res.read_values), np.asarray(vals[..., :Rd, :]))
    # committed values visible afterwards
    state, rep, _, _ = R.rpc_call(
        t, state, node[..., :0 + B * Wr * 0 + B * Wr] if False else
        ht.lookup_start(cfg, layout, klo[..., Rd:].reshape(N, -1),
                        khi[..., Rd:].reshape(N, -1))[0],
        ht.make_record(R.OP_LOOKUP, klo[..., Rd:].reshape(N, -1),
                       khi[..., Rd:].reshape(N, -1)), h)
    np.testing.assert_array_equal(
        np.asarray(rep[..., 3:]),
        np.asarray(new_vals.reshape(N, -1, sl.VALUE_WORDS)))


def test_transactions_write_conflict_aborts(cfg, layout):
    """Two lanes writing the SAME key: exactly one lock wins per round."""
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 4
    klo = jnp.full((N, B, 1), 1234, jnp.uint32)   # every lane, same key
    khi = jnp.zeros((N, B, 1), jnp.uint32)
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo.reshape(N, -1),
                                 khi.reshape(N, -1))
    state, _, _, _ = R.rpc_call(
        t, state, node,
        ht.make_record(R.OP_INSERT, klo.reshape(N, -1), khi.reshape(N, -1),
                       value=value_for(klo.reshape(N, -1))), h)
    read_keys = jnp.zeros((N, B, 0, 2), jnp.uint32)
    write_keys = jnp.stack([klo, khi], axis=-1)
    state, _, res = txm.run_transactions(
        t, state, cfg, layout, read_keys=read_keys, write_keys=write_keys,
        write_values=value_for(klo + jnp.uint32(7)))
    committed = np.asarray(res.committed)
    assert committed.sum() == 1, committed  # single winner cluster-wide
    # and the winner's unlock must leave the slot unlocked for the next round
    state, _, res2 = txm.run_transactions(
        t, state, cfg, layout, read_keys=read_keys, write_keys=write_keys,
        write_values=value_for(klo + jnp.uint32(9)))
    assert np.asarray(res2.committed).sum() == 1


def test_transaction_insert_new_key(cfg, layout):
    t = SimTransport(N)
    state = ht.init_cluster_state(cfg)
    B = 4
    klo, khi = make_keys(N * B, seed=11)
    klo, khi = klo.reshape(N, B, 1), khi.reshape(N, B, 1)
    read_keys = jnp.zeros((N, B, 0, 2), jnp.uint32)
    write_keys = jnp.stack([klo, khi], axis=-1)
    vals = value_for(klo)
    state, _, res = txm.run_transactions(
        t, state, cfg, layout, read_keys=read_keys, write_keys=write_keys,
        write_values=vals)
    assert bool(res.committed.all())
    h = ht.make_rpc_handler(cfg, layout)
    node, _, _ = ht.lookup_start(cfg, layout, klo.reshape(N, -1), khi.reshape(N, -1))
    state, rep, _, _ = R.rpc_call(
        t, state, node,
        ht.make_record(R.OP_LOOKUP, klo.reshape(N, -1), khi.reshape(N, -1)), h)
    np.testing.assert_array_equal(np.asarray(rep[..., 0]), R.ST_OK)
