"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes, + hypothesis property tests."""

import jax.numpy as jnp
import numpy as np
import pytest

try:  # degrade gracefully where hypothesis isn't installed: the property
    # tests still run as a deterministic fixed-sample sweep
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from repro.testing.hypothesis_stub import given, settings, st

from repro.core import slots as sl
from repro.core.datastructs import hashtable as ht
from repro.kernels import ops, ref
from repro.models.layers import attention_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, Sq, Sk, Hq, Hkv, D, causal, window, softcap, dtype)
    (1, 128, 128, 2, 2, 64, True, None, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, None, jnp.bfloat16),
    (1, 128, 128, 4, 1, 128, True, None, None, jnp.float32),
    (1, 256, 256, 2, 2, 64, True, 64, None, jnp.float32),     # sliding window
    (1, 128, 128, 2, 2, 64, True, None, 50.0, jnp.float32),   # softcap
    (1, 96, 160, 2, 2, 64, False, None, None, jnp.float32),   # cross, ragged
    (2, 192, 192, 2, 2, 32, True, None, None, jnp.float32),   # pad blocks
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_oracle(case):
    B, Sq, Sk, Hq, Hkv, D, causal, window, softcap, dtype = case
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, Sq, Hq, D), dtype) * 0.5
    k = jnp.asarray(rng.randn(B, Sk, Hkv, D), dtype) * 0.5
    v = jnp.asarray(rng.randn(B, Sk, Hkv, D), dtype) * 0.5
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_block=64, kv_block=64,
                              use_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window,
                         attn_softcap=softcap)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=1e-2)


@settings(max_examples=8, deadline=None)
@given(
    sq=st.sampled_from([64, 128, 192]),
    hq=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(sq, hq, g, d, causal):
    if hq % g:
        g = 1
    rng = np.random.RandomState(sq + hq + d)
    q = jnp.asarray(rng.randn(1, sq, hq, d), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, sq, hq // g, d), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, sq, hq // g, d), jnp.float32) * 0.3
    got = ops.flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64,
                              use_pallas=True, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("width", [1, 2, 4])
@pytest.mark.parametrize("n_keys", [8, 32])
def test_hash_probe_matches_oracle_and_table(width, n_keys):
    cfg = ht.HashTableConfig(n_nodes=1, n_buckets=64, bucket_width=width,
                             n_overflow=16)
    layout = ht.build_layout(cfg)
    from repro.core import rpc as R
    from repro.core.transport import SimTransport
    t = SimTransport(1)
    state = ht.init_cluster_state(cfg)
    rng = np.random.RandomState(1)
    klo = jnp.asarray(rng.randint(0, 2**31, n_keys), jnp.uint32)[None]
    khi = jnp.asarray(rng.randint(0, 2**31, n_keys), jnp.uint32)[None]
    vals = sl._mix32(klo[..., None] + jnp.arange(sl.VALUE_WORDS, dtype=jnp.uint32))
    node = jnp.zeros((1, n_keys), jnp.int32)
    h = ht.make_rpc_handler(cfg, layout)
    state, rep, _, _ = R.rpc_call(
        t, state, node, ht.make_record(R.OP_INSERT, klo, khi, value=vals), h)
    assert np.all(np.asarray(rep[..., 0]) == R.ST_OK)

    arena = state["arena"][0]
    _, bucket = ht.home_of(cfg, klo[0], khi[0])
    got = ops.hash_probe(arena, bucket.astype(jnp.int32), klo[0], khi[0],
                         width=width, use_pallas=True, interpret=True)
    want = ref.hash_probe_ref(arena, bucket.astype(jnp.int32), klo[0], khi[0],
                              width=width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every in-bucket key is found with the right value; chained keys are
    # exactly the (found == 0) ones
    found = np.asarray(got[:, 0]).astype(bool)
    if found.any():
        np.testing.assert_array_equal(np.asarray(got[found][:, 2:]),
                                      np.asarray(vals[0])[found])
    # missing keys never match
    miss = ops.hash_probe(arena, bucket.astype(jnp.int32), klo[0] + 1,
                          khi[0], width=width, use_pallas=True, interpret=True)
    assert not np.asarray(miss[:, 0]).any()


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (B, nc, Q, H, P, N, h_tile)
    (1, 2, 32, 4, 16, 16, 4),
    (2, 4, 64, 8, 32, 32, 4),
    (1, 3, 16, 2, 64, 128, 2),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case):
    B, nc, Q, H, P, N, h_tile = case
    rng = np.random.RandomState(2)
    xdt = jnp.asarray(rng.randn(B, nc, Q, H, P), jnp.float32) * 0.1
    dA = -jnp.asarray(rng.rand(B, nc, Q, H), jnp.float32) * 0.5
    Bc = jnp.asarray(rng.randn(B, nc, Q, N), jnp.float32) * 0.3
    Cc = jnp.asarray(rng.randn(B, nc, Q, N), jnp.float32) * 0.3
    y, st_ = ops.ssd_scan(xdt, dA, Bc, Cc, h_tile=h_tile, use_pallas=True,
                          interpret=True)
    yr, str_ = ref.ssd_scan_ref(xdt, dA, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(str_), atol=1e-4,
                               rtol=1e-3)


def test_ssd_scan_matches_model_chunked():
    """The kernel agrees with the model's ssd_chunked (same fold-in)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N, Q = 2, 128, 4, 16, 32, 32
    rng = np.random.RandomState(3)
    xh = jnp.asarray(rng.randn(B, S, H, P), jnp.float32) * 0.2
    dt = jnp.asarray(rng.rand(B, S, H), jnp.float32) * 0.5 + 0.1
    A = -jnp.asarray(rng.rand(H), jnp.float32) - 0.1
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32) * 0.3
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32) * 0.3
    y_model, st_model = ssd_chunked(xh, dt, A, Bm, Cm, Q)
    nc = S // Q
    resh = lambda t: t.reshape((B, nc, Q) + t.shape[2:])
    y_k, st_k = ops.ssd_scan(resh(xh * dt[..., None]), resh(dt * A),
                             resh(Bm), resh(Cm), h_tile=2, use_pallas=True,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_k.reshape(B, S, H, P)),
        np.asarray(y_model, np.float32).astype(np.float32), atol=2e-2,
        rtol=1e-2)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_model),
                               atol=1e-3, rtol=1e-3)
