"""Ordered remote index (B-link tree): handler semantics, structural
invariants across splits, leaf locking (incl. the lock-time pre-split that
keeps commits space-safe), the generic one-two-sided probe, and the
wireproto single-registration satellite."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid as hy
from repro.core import rpc as R
from repro.core import slots as sl
from repro.core import wireproto as W
from repro.core.datastructs import btree as bt
from repro.core.datastructs import hashtable as ht
from repro.core.transport import SimTransport
from repro.testing.workloads import distinct_uint32, value_for

N = 4


@pytest.fixture(scope="module")
def cfg():
    return bt.BTreeConfig(n_nodes=N, n_leaves=16, leaf_width=4,
                          max_scan_leaves=4)


@pytest.fixture(scope="module")
def layout(cfg):
    return bt.build_layout(cfg)


def rpc(t, state, cfg, layout, op, keys, aux=None, values=None, key_hi=None,
        dest=None):
    h = bt.make_rpc_handler(cfg, layout)
    dest = bt.home_of(cfg, keys) if dest is None else dest
    kh = jnp.zeros_like(keys) if key_hi is None else key_hi
    recs = bt.make_record(op, keys, kh, aux=aux, value=values)
    state, rep, ovf, _ = R.rpc_call(t, state, dest, recs, h)
    assert not bool(np.asarray(ovf).any())
    return state, np.asarray(rep)


def node_keys(cfg, n_per_node, seed=0):
    """n distinct keys inside every node's partition: (N, n) uint32."""
    rng = np.random.RandomState(seed)
    lo, hi = (np.asarray(x) for x in
              bt.partition_bounds(cfg, jnp.arange(N, dtype=jnp.int32)))
    out = np.stack([
        np.sort(distinct_uint32(rng, n_per_node, int(lo[n]), int(hi[n])))
        for n in range(N)])
    return jnp.asarray(out, jnp.uint32)


def walk_leaves(state, cfg, layout, node):
    """Follow right-links from leaf 0, asserting every B-link invariant:
    fences tile the node's partition with no gap or overlap, records are
    sorted and in-fence, the separator directory mirrors the fences, and
    the walk visits every allocated leaf.  Returns the ordered key list."""
    arena = np.asarray(state["arena"])[node]
    lv = layout["leaves"]
    nleaf = int(arena[layout["nleaf"].base])
    sep = arena[layout["sep"].base:layout["sep"].base + nleaf]
    leaves = arena[lv.base:lv.base + cfg.n_leaves * cfg.leaf_words].reshape(
        cfg.n_leaves, cfg.leaf_slots, sl.SLOT_WORDS)
    p_lo, p_hi = (int(np.asarray(x)) for x in
                  bt.partition_bounds(cfg, jnp.int32(node)))
    i, prev_hi, seen, keys = 0, p_lo - 1, 0, []
    while True:
        hdr = leaves[i, 0]
        flo, fhi = int(hdr[sl.KEY_LO]), int(hdr[sl.KEY_HI])
        cnt = int(hdr[sl.VALUE0])
        assert flo == prev_hi + 1, "fence gap/overlap"
        assert int(hdr[sl.VERSION]) % 2 == 0
        assert (sep == flo).sum() == 1, "separator directory out of sync"
        ks = leaves[i, 1:1 + cnt, sl.KEY_LO].tolist()
        assert ks == sorted(ks) and all(flo <= k <= fhi for k in ks)
        keys += ks
        prev_hi, seen = fhi, seen + 1
        nxt = int(hdr[sl.NEXT_PTR])
        if nxt == 0xFFFFFFFF:
            break
        i = nxt
    assert prev_hi == p_hi, "chain must end at the partition bound"
    assert seen == nleaf, "walk must visit every allocated leaf"
    return keys


def test_insert_lookup_update_delete(cfg, layout):
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    keys = node_keys(cfg, 10)
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_INSERT, keys,
                     values=value_for(keys))
    assert (rep[..., 0] == W.ST_OK).all()

    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOOKUP, keys)
    assert (rep[..., 0] == W.ST_OK).all()
    np.testing.assert_array_equal(rep[..., 3:], np.asarray(value_for(keys)))

    # upsert: re-insert with a different value updates in place
    v2 = value_for(keys + jnp.uint32(3))
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_INSERT, keys, values=v2)
    assert (rep[..., 0] == W.ST_OK).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOOKUP, keys)
    np.testing.assert_array_equal(rep[..., 3:], np.asarray(v2))

    # delete the even columns; they disappear, the rest stay, and absent
    # deletes report NOT_FOUND
    dk = keys[:, ::2]
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_DELETE, dk)
    assert (rep[..., 0] == W.ST_OK).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_DELETE, dk)
    assert (rep[..., 0] == W.ST_NOT_FOUND).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOOKUP, keys)
    st = rep[..., 0]
    assert (st[:, ::2] == W.ST_NOT_FOUND).all() and (st[:, 1::2] == W.ST_OK).all()
    for n in range(N):
        assert walk_leaves(state, cfg, layout, n) == \
            sorted(int(k) for k in np.asarray(keys)[n, 1::2])


def test_split_invariants_and_vector_lookup(cfg, layout):
    """Enough inserts to split repeatedly; every B-link invariant holds and
    every key stays findable (serial AND vector lookup handlers)."""
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    keys = node_keys(cfg, 24, seed=3)   # 24 keys -> several splits per node
    for i in range(0, 24, 8):           # batched so shapes stay identical
        state, rep = rpc(t, state, cfg, layout, W.OP_BT_INSERT,
                         keys[:, i:i + 8], values=value_for(keys[:, i:i + 8]))
        assert (rep[..., 0] == W.ST_OK).all()
    for n in range(N):
        assert walk_leaves(state, cfg, layout, n) == \
            sorted(int(k) for k in np.asarray(keys)[n]), "keys lost by splits"
    vec = bt.make_lookup_handler_vector(cfg, layout)
    _, rep, _, _ = R.rpc_call(t, state, bt.home_of(cfg, keys),
                              bt.make_record(W.OP_BT_LOOKUP, keys,
                                             jnp.zeros_like(keys)), vec)
    assert (np.asarray(rep[..., 0]) == W.ST_OK).all()
    np.testing.assert_array_equal(np.asarray(rep[..., 3:]),
                                  np.asarray(value_for(keys)))


def test_leaf_exhaustion_reports_no_space(layout):
    """A tree out of leaves back-pressures with ST_NO_SPACE and loses
    nothing it already holds."""
    small = bt.BTreeConfig(n_nodes=N, n_leaves=2, leaf_width=2,
                           max_scan_leaves=2)
    lay = bt.build_layout(small)
    t = SimTransport(N)
    state = bt.init_cluster_state(small)
    keys = node_keys(small, 8, seed=5)
    state, rep = rpc(t, state, small, lay, W.OP_BT_INSERT, keys,
                     values=value_for(keys))
    st = rep[..., 0]
    assert (st == W.ST_NO_SPACE).any(), "capacity 2x2 must exhaust on 8 keys"
    assert ((st == W.ST_OK) | (st == W.ST_NO_SPACE)).all()
    state, rep2 = rpc(t, state, small, lay, W.OP_BT_LOOKUP, keys)
    np.testing.assert_array_equal(rep2[..., 0] == W.ST_OK, st == W.ST_OK)
    for n in range(N):
        walk_leaves(state, small, lay, n)   # invariants survive exhaustion


def test_leaf_lock_blocks_mutations_and_unlocks(cfg, layout):
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    keys = node_keys(cfg, 4, seed=7)
    state, _ = rpc(t, state, cfg, layout, W.OP_BT_INSERT, keys,
                   values=value_for(keys))
    k0 = keys[:, :1]
    tag = jnp.full(k0.shape, 77, jnp.uint32)
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOCK, k0, aux=tag)
    assert (rep[..., 0] == W.ST_OK).all()
    hslot = jnp.asarray(rep[..., 1], jnp.uint32)
    lock_ver = rep[..., 2].copy()
    # read-for-update: the LOCK reply carries the current value
    np.testing.assert_array_equal(rep[..., 3:], np.asarray(value_for(k0)))

    # the LEAF is locked: mutating the same key or a sibling key both fail
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_INSERT, k0,
                     values=value_for(k0))
    assert (rep[..., 0] == W.ST_LOCK_FAIL).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_DELETE, k0)
    assert (rep[..., 0] == W.ST_LOCK_FAIL).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOCK, k0,
                     aux=tag + jnp.uint32(1))
    assert (rep[..., 0] == W.ST_LOCK_FAIL).all()

    # unlock ownership requires the EXACT tag
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_ABORT, k0,
                     key_hi=tag + jnp.uint32(1), aux=hslot)
    assert (rep[..., 0] == W.ST_LOCK_FAIL).all()
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_ABORT, k0, key_hi=tag,
                     aux=hslot)
    assert (rep[..., 0] == W.ST_OK).all()
    # abort released without bumping: versions unchanged, mutations work
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_LOOKUP, k0)
    np.testing.assert_array_equal(rep[..., 2], lock_ver)
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_DELETE, k0)
    assert (rep[..., 0] == W.ST_OK).all()


def test_lock_presplits_full_leaf_then_commit(layout):
    """OP_BT_LOCK on a FULL leaf pre-splits it (split on the way down), so
    OP_BT_COMMIT always has room; the committed version is the predicted
    lock_ver + 2 and every invariant survives."""
    small = bt.BTreeConfig(n_nodes=N, n_leaves=8, leaf_width=2,
                           max_scan_leaves=2)
    lay = bt.build_layout(small)
    t = SimTransport(N)
    state = bt.init_cluster_state(small)
    base = node_keys(small, 2, seed=9)      # exactly fills leaf 0 (width 2)
    state, rep = rpc(t, state, small, lay, W.OP_BT_INSERT, base,
                     values=value_for(base))
    assert (rep[..., 0] == W.ST_OK).all()
    nleaf0 = np.asarray(state["arena"])[:, lay["nleaf"].base].copy()

    # one above each node's largest key: guaranteed absent, still inside the
    # partition (node_keys draws below the inclusive bound), same (only) leaf
    fresh = base[:, 1:2] + jnp.uint32(1)
    tag = jnp.full(fresh.shape, 5, jnp.uint32)
    state, rep = rpc(t, state, small, lay, W.OP_BT_LOCK, fresh, aux=tag)
    assert (rep[..., 0] == W.ST_OK).all()
    nleaf1 = np.asarray(state["arena"])[:, lay["nleaf"].base]
    assert (nleaf1 == nleaf0 + 1).all(), "lock must pre-split the full leaf"
    hslot, lock_ver = jnp.asarray(rep[..., 1], jnp.uint32), rep[..., 2]

    state, rep = rpc(t, state, small, lay, W.OP_BT_COMMIT, fresh, key_hi=tag,
                     aux=hslot, values=value_for(fresh))
    assert (rep[..., 0] == W.ST_OK).all()
    np.testing.assert_array_equal(rep[..., 2], lock_ver + 2)
    state, rep = rpc(t, state, small, lay, W.OP_BT_LOOKUP, fresh)
    assert (rep[..., 0] == W.ST_OK).all()
    np.testing.assert_array_equal(rep[..., 3:], np.asarray(value_for(fresh)))
    for n in range(N):
        ks = walk_leaves(state, small, lay, n)
        assert int(np.asarray(fresh)[n, 0]) in ks


def test_hybrid_probe_onesided_fast_path_and_stale_fallback(cfg, layout):
    """The generic probe (hybrid ds=btree): fresh separators resolve every
    lookup with ONE one-sided read — including validated MISSES, which need
    no RPC (unlike the hash table); stale separators fall back to RPC and
    still resolve."""
    t = SimTransport(N)
    state = bt.init_cluster_state(cfg)
    keys = node_keys(cfg, 12, seed=11)
    state, _ = rpc(t, state, cfg, layout, W.OP_BT_INSERT, keys,
                   values=value_for(keys))
    meta = bt.local_meta(cfg, layout, state)

    kk = keys[:, ::2]
    state, _, found, val, ver, _, _, ovf, m = hy.hybrid_lookup(
        t, state, kk, jnp.zeros_like(kk), cfg, layout, cache=meta, ds=bt)
    assert bool(np.asarray(found).all())
    assert float(m.rpc_fallback) == 0.0, "fresh meta must be pure one-sided"
    np.testing.assert_array_equal(np.asarray(val), np.asarray(value_for(kk)))
    assert (np.asarray(ver) % 2 == 0).all()

    # a validated miss is RESOLVED one-sided: no fallback, found=False
    miss = kk + jnp.uint32(1)
    state, _, found, _, _, _, _, _, m2 = hy.hybrid_lookup(
        t, state, miss, jnp.zeros_like(miss), cfg, layout, cache=meta, ds=bt)
    assert not bool(np.asarray(found).any())
    assert float(m2.rpc_fallback) == 0.0, \
        "an in-fence stable miss needs no RPC (definitive absence)"

    # stale meta: splits after the snapshot -> fallback resolves
    extra = keys + jnp.uint32(1)
    state, rep = rpc(t, state, cfg, layout, W.OP_BT_INSERT, extra,
                     values=value_for(extra))
    assert (rep[..., 0] == W.ST_OK).all()
    state, _, found, val, _, _, _, _, m3 = hy.hybrid_lookup(
        t, state, extra, jnp.zeros_like(extra), cfg, layout, cache=meta,
        ds=bt)
    assert bool(np.asarray(found).all())
    assert float(m3.rpc_fallback) > 0.0, "stale route must use the fallback"
    np.testing.assert_array_equal(np.asarray(val),
                                  np.asarray(value_for(extra)))
    # refreshed meta restores the pure one-sided fast path
    meta2, _ = bt.refresh_meta(t, state, cfg, layout)
    state, _, found, _, _, _, _, _, m4 = hy.hybrid_lookup(
        t, state, extra, jnp.zeros_like(extra), cfg, layout, cache=meta2,
        ds=bt)
    assert bool(np.asarray(found).all()) and float(m4.rpc_fallback) == 0.0


def test_wireproto_is_the_single_registration_point():
    """Satellite: rpc.py re-exports ARE wireproto's constants (one place to
    register an opcode), and both data structures' record builders stamp
    them into word 0."""
    for name in dir(W):
        if name.startswith(("OP_", "ST_")):
            assert getattr(R, name) == getattr(W, name), name
    rec = ht.make_record(W.OP_LOOKUP, jnp.uint32(1), jnp.uint32(2))
    assert int(rec[0]) == W.OP_LOOKUP
    rec = bt.make_record(W.OP_BT_SCAN, jnp.uint32(1), jnp.uint32(0))
    assert int(rec[0]) == W.OP_BT_SCAN
    # the two structures' opcode blocks never collide
    ht_ops = {W.OP_NOP, W.OP_LOOKUP, W.OP_INSERT, W.OP_UPDATE, W.OP_DELETE,
              W.OP_LOCK, W.OP_COMMIT_UNLOCK, W.OP_ABORT_UNLOCK,
              W.OP_READ_VERSION, W.OP_BACKUP_WRITE}
    bt_ops = {W.OP_BT_LOOKUP, W.OP_BT_INSERT, W.OP_BT_DELETE, W.OP_BT_LOCK,
              W.OP_BT_COMMIT, W.OP_BT_ABORT, W.OP_BT_SCAN, W.OP_BT_BACKUP}
    assert not (ht_ops & bt_ops)
    assert len(ht_ops) == 10 and len(bt_ops) == 8
