"""Serving correctness: prefill+decode must reproduce the teacher-forced
forward pass (per architecture, reduced configs, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import api
from repro.models.transformer import RunOptions
from repro.parallel.sharding import Topology, init_params
from repro.serving.decode import init_cache, make_decode_step, make_prefill

OPTS = RunOptions(q_block=16, kv_block=16, remat=False)
PROMPT, DECODE = 24, 4


def smoke_topo():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return Topology(mesh)


def grow_kv(cache, names, new_S):
    out = dict(cache)
    for n in names:
        c = cache[n]
        pad = new_S - c.shape[2]
        out[n] = jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    # capacity_factor high enough that no token is ever dropped: capacity
    # routing legitimately differs between a 2-token decode batch and the
    # full forward, so parity needs the no-drop regime.
    cfg = dataclasses.replace(ARCHS[arch].smoke(), capacity_factor=16.0)
    topo = smoke_topo()
    params = init_params(api.param_specs(cfg), jax.random.key(0))
    B, total = 2, PROMPT + DECODE
    shape = ShapeConfig("t", total, B, "train")
    batch = synthetic_batch(cfg, shape, DataConfig(), 0)
    tokens = batch["tokens"]

    # teacher-forced reference over the full sequence
    full = dict(batch)
    full.pop("labels")
    ref_logits = jax.jit(
        lambda p, b: api.forward(cfg, topo, p, b, opts=OPTS))(params, full)

    # prefill on the prompt
    pre_batch = {k: (v[:, :PROMPT] if k in ("tokens", "labels") else v)
                 for k, v in full.items()}
    prefill = make_prefill(cfg, topo, PROMPT, OPTS)
    logits_p, cache = jax.jit(prefill)(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(ref_logits[:, PROMPT - 1], np.float32), atol=0.3, rtol=0.1)

    # grow the cache and decode token by token
    if cfg.family in ("dense", "moe", "vlm"):
        cache = grow_kv(cache, ("k", "v"), total)
    elif cfg.family == "hybrid":
        cache = grow_kv(cache, ("shared_k", "shared_v"), total)
    elif cfg.family == "audio":
        cache = grow_kv(cache, ("k", "v"), total)
    step = jax.jit(make_decode_step(cfg, topo))
    for t in range(PROMPT, total):
        logits_d, cache = step(params, cache, tokens[:, t])
        ref_t = np.asarray(ref_logits[:, t], np.float32)
        got = np.asarray(logits_d, np.float32)
        np.testing.assert_allclose(got, ref_t, atol=0.12, rtol=0.05)
        # argmax must agree unless the ref's own top-2 margin is within
        # bf16 noise of the observed deviation
        margin = np.sort(ref_t, -1)[:, -1] - np.sort(ref_t, -1)[:, -2]
        flip = np.argmax(got, -1) != np.argmax(ref_t, -1)
        dev = np.abs(got - ref_t).max()
        assert not np.any(flip & (margin > 4 * dev)), (t, margin, dev)


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "mamba2-780m"])
def test_decode_from_empty_cache(arch):
    """Decode-only path: start from an empty cache (len=0) and free-run."""
    cfg = ARCHS[arch].smoke()
    topo = smoke_topo()
    params = init_params(api.param_specs(cfg), jax.random.key(1))
    B, S = 2, 16
    cache = init_cache(cfg, topo, B, S)
    step = jax.jit(make_decode_step(cfg, topo))
    tok = jnp.ones((B,), jnp.int32)
    for _ in range(4):
        logits, cache = step(params, cache, tok)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["len"][0]) == 4
